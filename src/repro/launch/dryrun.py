import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# initialization.  Dry-run only — tests/benchmarks see the 1 real CPU.
"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input-shape) cell on the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, prints
``memory_analysis`` / ``cost_analysis``, and caches the full roofline
record per cell under benchmarks/results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
      --shape train_4k --mesh pod --tag mb4 --microbatch 4   # hillclimb
"""
import argparse
import json
import sys
import traceback

from repro.configs import ARCH_IDS, get_config
from repro.launch.dryrun_lib import (CellOptions, result_path, run_cell,
                                     save_result)
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES_BY_NAME, runnable

DEFAULT_OUT = "benchmarks/results/dryrun"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    # hillclimb levers
    ap.add_argument("--remat", default="full",
                    choices=("none", "full", "dots"))
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--seq-axis", default=None)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--prefill-last-only", action="store_true")
    ap.add_argument("--scan", action="store_true",
                    help="scan-over-depth form (default for multipod)")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = (sorted(SHAPES_BY_NAME) if (args.all or not args.shape)
              else [args.shape])
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_tag in meshes:
        # Single-pod cells get the exact-cost extrapolation pass; the
        # multi-pod sweep is the compile/sharding proof only.
        exact = (mesh_tag == "pod") and not args.scan
        opts = CellOptions(remat=args.remat, microbatch=args.microbatch,
                           zero1=args.zero1, seq_axis=args.seq_axis,
                           loss_chunk=args.loss_chunk, tag=args.tag,
                           prefill_last_only=args.prefill_last_only,
                           exact_costs=exact)
        mesh = make_production_mesh(multi_pod=(mesh_tag == "multipod"))
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                shape = SHAPES_BY_NAME[shape_name]
                runs, reason = runnable(cfg, shape)
                path = result_path(args.out, arch, shape_name, mesh_tag,
                                   args.tag)
                if not runs:
                    save_result(path, {
                        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
                        "tag": args.tag, "skipped": True, "reason": reason,
                    })
                    print(f"[skip] {arch} x {shape_name} ({reason})")
                    continue
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {arch} x {shape_name} x {mesh_tag}")
                    continue
                print(f"[dryrun] {arch} x {shape_name} x {mesh_tag} ...",
                      flush=True)
                try:
                    rec = run_cell(cfg, shape, mesh, opts)
                except Exception as e:  # noqa: BLE001 — report all failures
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_tag, repr(e)))
                    continue
                save_result(path, rec)
                t = rec["terms_s"]
                print(
                    f"  ok: lower {rec['lower_s']:.1f}s compile "
                    f"{rec['compile_s']:.1f}s | peak/dev "
                    f"{rec['peak_bytes_per_device']/2**30:.2f} GiB "
                    f"(fits={rec['fits_hbm']}) | compute {t['compute_s']*1e3:.2f}ms "
                    f"memory {t['memory_s']*1e3:.2f}ms coll "
                    f"{t['collective_s']*1e3:.2f}ms -> {rec['dominant']}",
                    flush=True,
                )
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall requested cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
