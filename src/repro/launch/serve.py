"""Serving launcher: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import TransformerLM
from repro.serve.engine import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.new_tokens)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s); first row: {out[0][:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
