"""Assigned input shapes and the (arch x shape) cell gating.

Four LM shapes per architecture (seq_len x global_batch):
  * train_4k     — training step       (4,096 x 256)
  * prefill_32k  — inference prefill   (32,768 x 32)
  * decode_32k   — one decode step against a 32,768-token KV cache x 128
  * long_500k    — one decode step against a 524,288-token context x 1
                   (sub-quadratic archs only; pure full-attention archs
                   skip per the assignment — the skip matrix lives in
                   DESIGN.md §5 and is encoded by ``runnable`` below)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "runnable", "cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, Optional[str]]:
    """(runs?, skip-reason).  long_500k needs a bounded or sub-quadratic
    per-layer state: any recurrence or window qualifies; pure
    full-attention stacks (every layer 'global') skip."""
    if shape.name == "long_500k":
        if all(k == "global" for k in cfg.attn_pattern):
            return False, ("pure full-attention arch: 512k dense KV cache "
                           "with no windowing mechanism in the published "
                           "architecture (assignment skip rule)")
    return True, None


def cells(configs: dict):
    """Yield (arch, cfg, shape, runs, reason) for the full 40-cell grid."""
    for arch, cfg in configs.items():
        for shape in SHAPES:
            runs, reason = runnable(cfg, shape)
            yield arch, cfg, shape, runs, reason
