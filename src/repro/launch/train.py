"""Training launcher.

CPU-scale entry point exercising the full production path (config ->
mesh -> sharded train step -> checkpointed loop).  On a real TPU pod
the same driver runs with ``--mesh pod|multipod`` after
``jax.distributed.initialize()``; on CPU it defaults to a 1x1 mesh.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticLMData
from repro.dist.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models.transformer import TransformerLM
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=("none", "full", "dots"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("auto", "pod", "multipod"),
                    default="auto")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "auto":
        n = len(jax.devices())
        mesh = make_mesh((1, n), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    policy = ShardingPolicy.for_mesh(mesh)

    model = TransformerLM(cfg, remat=args.remat)
    data = SyntheticLMData(cfg.vocab_size, args.batch, args.seq,
                           seed=args.seed)
    trainer = Trainer(
        model, AdamWConfig(lr=args.lr, total_steps=max(args.steps, 10)),
        mesh, policy, data, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatch=args.microbatch,
        seed=args.seed)
    trainer.install_preemption_handler()
    report = trainer.run(args.steps)
    print(f"arch={cfg.name} steps={report.steps_run} "
          f"resumed_from={report.resumed_from} "
          f"loss[0]={report.losses[0]:.4f} loss[-1]={report.losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
