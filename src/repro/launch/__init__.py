"""Launchers: production meshes, assigned shape cells, and dry-runs.

``mesh.py`` names the production mesh shapes; ``shapes.py`` pins the
(architecture x input-shape) cell matrix the launchers are gated on;
``serve.py`` and ``train.py`` are the CLI entry points wiring configs
into :class:`repro.serve.ServeEngine` and :mod:`repro.train`
respectively; ``dryrun_lib.py``/``dryrun.py`` build, lower, and
compile any cell WITHOUT executing it — the abstract-params path the
static analysis layer (:mod:`repro.analysis`) shares, so "does this
cell lower on this mesh" is answerable on a laptop before burning
accelerator time.
"""
