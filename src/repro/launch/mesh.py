"""Production meshes.

Exposed as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its
first jax import, while smoke tests and benchmarks see the 1 real CPU
device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e production topology: 16x16 (256 chips) per pod; the
    multi-pod mesh adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices=None) -> Mesh:
    """Mesh over the first prod(shape) available devices.

    Unlike ``jax.make_mesh`` this tolerates a surplus of devices (the
    dry-run holds 512 host devices but the single-pod mesh uses 256).
    """
    n = int(np.prod(shape))
    devices = list(devices or jax.devices())
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def mesh_axes(mesh: Mesh):
    """(data_axes, model_axis) for a production mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    return data_axes, "model"
