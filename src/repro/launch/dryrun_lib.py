"""Dry-run library: build, lower, compile, and analyze any cell.

Importable without touching device state — the CLI wrapper
(``dryrun.py``) sets ``XLA_FLAGS`` *before* importing this module.

``run_cell`` lowers the cell's computation onto the given mesh with
ShapeDtypeStruct stand-ins (zero allocation), compiles, and extracts:

* ``memory_analysis``  — per-device argument/output/temp bytes (the
  "does it fit 16 GB v5e HBM" proof);
* ``cost_analysis``    — per-device HLO FLOPs and bytes accessed;
* collective traffic   — parsed from the post-SPMD HLO text: per-device
  operand bytes of all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute, by type;
* the three roofline terms + MODEL_FLOPS ratio (§Roofline).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Optional

import jax
import numpy as np

# ---- TPU v5e hardware constants (assignment-specified) --------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (per-device collective bytes / this)
HBM_BYTES = 16 * 1024**3        # v5e HBM capacity
DEFAULT_LOSS_CHUNK = 512        # sequence-chunked CE (see build_cell)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective bytes by op type from post-SPMD HLO."""
    by_type: dict = {}
    count = 0
    largest: list = []
    for m in _COLL_RE.finditer(hlo_text):
        typestr, op = m.group(1), m.group(2)
        b = _shape_bytes(typestr)
        agg = by_type.setdefault(op, {"bytes": 0, "count": 0})
        agg["bytes"] += b
        agg["count"] += 1
        count += 1
        largest.append((b, op))
    largest.sort(reverse=True)
    return {
        "total_bytes": sum(v["bytes"] for v in by_type.values()),
        "count": count,
        "by_type": by_type,
        "largest": [
            {"bytes": b, "op": op} for b, op in largest[:8]
        ],
    }


@dataclasses.dataclass
class CellOptions:
    """Per-cell knobs — the §Perf hillclimb levers."""

    remat: str = "full"           # train-cell remat policy
    microbatch: int = 1
    zero1: bool = False
    seq_axis: Optional[str] = None
    loss_chunk: Optional[int] = None
    exact_costs: bool = True      # add the 1-group/2-group unrolled pass
                                  # (exact linear cost extrapolation); the
                                  # multi-pod compile proof skips it
    unroll: bool = False          # model form for the MAIN compile
    fsdp: Optional[bool] = None   # None = auto by full-model state size;
                                  # resolved ONCE per cell so the small
                                  # extrapolation models match the full
                                  # model's sharding regime
    opt_state_dtype: str = "float32"  # "bfloat16" = half-width moments
    prefill_last_only: bool = False   # serve-style prefill (last-token
                                      # logits only) — §Perf lever
    tag: str = "baseline"


def _policy(mesh, opts: CellOptions, cfg=None, kind: str = "train"):
    """Cell sharding policy.  FSDP (+ZeRO-1 for train) switches on
    automatically when TP-only state would exceed ~35% of v5e HBM —
    the production choice for the 100B+ MoE archs."""
    from repro.dist.sharding import ShardingPolicy
    if opts.fsdp is not None:
        fsdp = opts.fsdp
    else:
        fsdp = opts.zero1
        if cfg is not None:
            n = cfg.param_counts()["total"]
            per_param = 10 if kind == "train" else 2  # bf16 (+f32 m,v)
            msize = dict(zip(mesh.axis_names,
                             mesh.devices.shape)).get("model", 1)
            tp_state = n * per_param / msize
            if tp_state > 0.35 * HBM_BYTES:
                fsdp = True
    return ShardingPolicy.for_mesh(
        mesh, zero1=opts.zero1 or (fsdp and kind == "train"),
        seq_axis=opts.seq_axis, fsdp=fsdp)


def build_cell(cfg, shape, mesh, opts: CellOptions):
    """Returns (jitted_fn, arg_shapes tuple) — nothing allocated."""
    from repro.dist.sharding import ShardingPolicy  # noqa: F401
    from repro.models.transformer import TransformerLM
    from repro.serve.engine import build_decode_step, build_prefill_step
    from repro.train.optimizer import AdamWConfig
    from repro.train.step import build_train_step, init_train_state

    policy = _policy(mesh, opts, cfg=cfg, kind=shape.kind)
    b, s = shape.global_batch, shape.seq_len
    embeds_in = cfg.frontend == "vision"

    if shape.kind == "train":
        model = TransformerLM(cfg, remat=opts.remat, unroll=opts.unroll)
        # Baseline uses sequence-chunked CE: materializing full
        # [b, s, 256k-vocab] f32 logits plus softmax temps exceeds HBM
        # for the gemma-family archs (27.9 GiB/dev measured), and every
        # production LM framework chunks or fuses big-vocab CE.
        chunk = opts.loss_chunk or DEFAULT_LOSS_CHUNK
        if chunk and s % chunk == 0 and s > chunk:
            model = _with_chunked_loss(model, chunk)
        ocfg = AdamWConfig(state_dtype=opts.opt_state_dtype)
        step, state_sh, _ = build_train_step(
            model, ocfg, mesh, policy,
            microbatch=opts.microbatch,
            input_kind="embeds" if embeds_in else "tokens")
        state_shapes = jax.eval_shape(
            lambda: init_train_state(model, jax.random.key(0),
                                     opts.opt_state_dtype))
        if embeds_in:
            x = jax.ShapeDtypeStruct((b, s, cfg.d_model), np.float32)
        else:
            x = jax.ShapeDtypeStruct((b, s), np.int32)
        y = jax.ShapeDtypeStruct((b, s), np.int32)
        return step, (state_shapes, x, y)

    model = TransformerLM(cfg, remat="none", unroll=opts.unroll)
    if shape.kind == "prefill":
        step, psh, _ = build_prefill_step(
            model, mesh, policy, last_only=opts.prefill_last_only)
        params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        if embeds_in:
            # vlm prefill consumes stub frontend embeddings
            def prefill_embeds(p, e):
                logits, _ = model.apply(p, embeds=e)
                return logits
            from jax.sharding import NamedSharding, PartitionSpec as P
            e_sh = NamedSharding(mesh, P(policy.batch_spec, policy.seq_axis,
                                         None))
            step = jax.jit(prefill_embeds, in_shardings=(psh, e_sh))
            x = jax.ShapeDtypeStruct((b, s, cfg.d_model), np.float32)
        else:
            x = jax.ShapeDtypeStruct((b, s), np.int32)
        return step, (params, x)

    if shape.kind == "decode":
        kv_seq_axis = None
        if shape.name == "long_500k" and any(
                k == "global" for k in cfg.attn_pattern):
            # single-sequence long context: shard the cache length over
            # the whole mesh (flash-decode-style distributed attention)
            kv_seq_axis = tuple(mesh.axis_names)
            kv_seq_axis = tuple(a for a in kv_seq_axis)  # all axes
        step, psh, csh = build_decode_step(
            model, mesh, policy, batch=b, cache_len=s,
            kv_seq_axis=kv_seq_axis)
        params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        tok = jax.ShapeDtypeStruct((b,), np.int32)
        pos = jax.ShapeDtypeStruct((), np.int32)
        return step, (params, cache, tok, pos)

    raise ValueError(shape.kind)


def _with_chunked_loss(model, chunk: int):
    """Sequence-chunked cross-entropy: never materializes the full
    [b, s, vocab] logits (memory-term hillclimb lever for 256k-vocab
    archs)."""
    import jax.numpy as jnp

    def chunked_loss(params, tokens=None, labels=None, embeds=None,
                     aux_coeff: float = 0.01):
        hidden, aux = model.hidden(params, tokens=tokens, embeds=embeds)
        b, s, d = hidden.shape
        assert s % chunk == 0
        hs = hidden.reshape(b, s // chunk, chunk, d).swapaxes(0, 1)
        ls = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)

        @jax.checkpoint
        def body(acc, xs):
            # rematerialized: the backward pass recomputes each chunk's
            # logits instead of keeping every [b, chunk, vocab] f32
            # block alive (4+ GiB/device for 256k vocabs otherwise)
            h, l = xs
            logits = model._unembed(params, h)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, l[..., None], axis=-1)
            return acc + jnp.sum(nll), None

        total = jnp.zeros((), jnp.float32)
        if model.unroll:
            # analysis form: unrolled so HloCostAnalysis counts every
            # chunk (a scan body is visited once — see exact_costs)
            for i in range(s // chunk):
                total, _ = body(total, (hs[i], ls[i]))
        else:
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                    (hs, ls))
        return total / (b * s) + aux_coeff * aux

    model.loss = chunked_loss
    return model


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------
def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)."""
    n_active = cfg.active_param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


def analyze(compiled, cfg, shape, n_devices: int) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    peak = (mem["argument_bytes"] + mem["output_bytes"]
            + mem["temp_bytes"] - mem["alias_bytes"])
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll["total_bytes"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_devices
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "memory": mem,
        "peak_bytes_per_device": int(peak),
        "fits_hbm": bool(peak <= HBM_BYTES),
        "terms_s": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "model_flops_per_device": mf_dev,
        "useful_compute_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        "step_time_bound_s": max(terms.values()),
        "mfu_bound": (mf_dev / PEAK_FLOPS_BF16) / max(terms.values())
        if max(terms.values()) > 0 else 0.0,
    }


def _compile_once(cfg, shape, mesh, opts: CellOptions):
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh, opts)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    return compiled, t_lower, t_compile


def _raw_costs(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "coll_count": float(coll["count"]),
        "coll": coll,
    }


def exact_costs(cfg, shape, mesh, opts: CellOptions) -> dict:
    """Exact per-step HLO costs via linear group extrapolation.

    XLA's HloCostAnalysis visits a while-loop body once regardless of
    trip count (verified in tests), so the scan-form numbers undercount
    depth.  Per-group cost is identical across groups, so with G groups:

        cost(G) = cost(1 group) + (G-1) * [cost(2 groups) - cost(1)]

    computed from two small *unrolled* compiles — exact for every
    quantity linear in depth (FLOPs, bytes, collective bytes/counts),
    with embed/loss/optimizer outer costs counted exactly once.
    """
    g_total = cfg.n_groups
    o = dataclasses.replace(opts, exact_costs=False, unroll=True)
    tail = len(cfg.pattern_tail)
    cfg1 = dataclasses.replace(cfg, n_layers=cfg.pattern_period + tail)
    c1_compiled, _, t1 = _compile_once(cfg1, shape, mesh, o)
    c1 = _raw_costs(c1_compiled)
    if g_total == 1:
        return {"flops": c1["flops"], "bytes": c1["bytes"],
                "coll_bytes": c1["coll_bytes"],
                "coll_count": c1["coll_count"],
                "coll_by_type": c1["coll"]["by_type"],
                "largest": c1["coll"]["largest"],
                "extrapolated_from": [1], "extra_compile_s": t1}
    cfg2 = dataclasses.replace(cfg, n_layers=2 * cfg.pattern_period + tail)
    c2_compiled, _, t2 = _compile_once(cfg2, shape, mesh, o)
    c2 = _raw_costs(c2_compiled)

    def lin(a, b):
        return a + (g_total - 1) * (b - a)

    by_type = {}
    for op in set(c1["coll"]["by_type"]) | set(c2["coll"]["by_type"]):
        b1 = c1["coll"]["by_type"].get(op, {"bytes": 0, "count": 0})
        b2 = c2["coll"]["by_type"].get(op, {"bytes": 0, "count": 0})
        by_type[op] = {"bytes": int(lin(b1["bytes"], b2["bytes"])),
                       "count": int(lin(b1["count"], b2["count"]))}
    return {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "coll_bytes": lin(c1["coll_bytes"], c2["coll_bytes"]),
        "coll_count": lin(c1["coll_count"], c2["coll_count"]),
        "coll_by_type": by_type,
        "largest": c2["coll"]["largest"],
        "extrapolated_from": [1, 2],
        "extra_compile_s": t1 + t2,
    }


def run_cell(cfg, shape, mesh, opts: CellOptions) -> dict:
    # Resolve the FSDP regime from the FULL model once, so the
    # small extrapolation models compile under the same sharding.
    if opts.fsdp is None:
        pol = _policy(mesh, opts, cfg=cfg, kind=shape.kind)
        opts = dataclasses.replace(opts, fsdp=pol.fsdp)
    # Pass 1 — deployment (scan) form: memory analysis + compile proof.
    # Train cells auto-scale gradient-accumulation microbatching until
    # the step fits HBM (the knob any production config would turn);
    # the microbatch used is recorded in the cell options.
    compiled, t_lower, t_compile = _compile_once(cfg, shape, mesh, opts)
    rec = analyze(compiled, cfg, shape, mesh.size)
    if shape.kind == "train" and not rec["fits_hbm"]:
        ladders = [dict(microbatch=mb) for mb in (2, 4, 8, 16)]
        # final rung: bf16 optimizer moments (100B-class squeeze)
        ladders += [dict(microbatch=mb, opt_state_dtype="bfloat16")
                    for mb in (8, 16)]
        for knobs in ladders:
            if shape.global_batch % knobs["microbatch"]:
                continue
            opts = dataclasses.replace(opts, **knobs)
            compiled, t_lower, t_compile = _compile_once(
                cfg, shape, mesh, opts)
            rec = analyze(compiled, cfg, shape, mesh.size)
            if rec["fits_hbm"]:
                break
    rec["scan_form_costs"] = {
        "flops_per_device": rec["flops_per_device"],
        "bytes_per_device": rec["bytes_per_device"],
        "note": "while-bodies counted once; see exact costs",
    }
    # Pass 2 — exact linear-extrapolated costs (single-pod analysis).
    if opts.exact_costs:
        ec = exact_costs(cfg, shape, mesh, opts)
        rec["flops_per_device"] = ec["flops"]
        rec["bytes_per_device"] = ec["bytes"]
        rec["collectives"] = {
            "total_bytes": ec["coll_bytes"],
            "count": ec["coll_count"],
            "by_type": ec["coll_by_type"],
            "largest": ec["largest"],
            "extrapolated_from": ec["extrapolated_from"],
        }
        rec["exact_cost_compile_s"] = ec["extra_compile_s"]
        terms = {
            "compute_s": ec["flops"] / PEAK_FLOPS_BF16,
            "memory_s": ec["bytes"] / HBM_BW,
            "collective_s": ec["coll_bytes"] / ICI_BW,
        }
        rec["terms_s"] = terms
        rec["dominant"] = max(terms, key=terms.get)
        mf_dev = rec["model_flops_per_device"]
        rec["useful_compute_ratio"] = (mf_dev / ec["flops"]
                                       if ec["flops"] else 0.0)
        rec["step_time_bound_s"] = max(terms.values())
        rec["mfu_bound"] = ((mf_dev / PEAK_FLOPS_BF16) / max(terms.values())
                            if max(terms.values()) > 0 else 0.0)
    rec.update({
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "n_devices": int(mesh.size),
        "tag": opts.tag,
        "opts": dataclasses.asdict(opts),
        "lower_s": t_lower,
        "compile_s": t_compile,
    })
    return rec


def result_path(out_dir: str, arch: str, shape: str, mesh_tag: str,
                tag: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_tag}__{tag}.json")


def save_result(path: str, rec: dict):
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
