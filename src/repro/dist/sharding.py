"""Static placement: ShardingPolicy + parameter/batch PartitionSpecs.

``param_specs`` is a pure map over parameter-tree *paths and shapes*
(it runs happily on ``jax.eval_shape`` output), so the placement of a
100B-parameter model is decided without allocating a byte.  The rule
table lives in the package docstring (:mod:`repro.dist`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

__all__ = ["ShardingPolicy", "param_specs", "batch_specs",
           "as_concrete_mesh"]

Axes = Union[None, str, Tuple[str, ...]]


def _mesh_axis_sizes(mesh) -> Tuple[Tuple[str, int], ...]:
    """(axis, size) pairs for a ``Mesh`` OR an ``AbstractMesh`` — the
    abstract form has no device array, only ``shape_tuple``."""
    shape_tuple = getattr(mesh, "shape_tuple", None)
    if shape_tuple is not None:
        return tuple((str(a), int(s)) for a, s in shape_tuple)
    return tuple(zip((str(a) for a in mesh.axis_names),
                     (int(s) for s in mesh.devices.shape)))


def as_concrete_mesh(mesh, devices=None) -> Mesh:
    """Bind an ``AbstractMesh`` description to this process's devices.

    This jax version cannot lower a computation whose shardings name an
    ``AbstractMesh`` (its ``_device_assignment`` is unimplemented), so
    dry-run partitioning binds the abstract description to compile-only
    devices — typically host CPU devices forced into existence with
    ``--xla_force_host_platform_device_count=N`` *before* jax
    initializes (``python -m repro.analysis --mesh N`` does this).
    A concrete ``Mesh`` passes through untouched.
    """
    if isinstance(mesh, Mesh):
        return mesh
    items = _mesh_axis_sizes(mesh)
    n = 1
    for _, s in items:
        n *= s
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"cannot bind abstract mesh {dict(items)} ({n} devices) to "
            f"{len(devices)} available device(s); force host devices "
            f"before jax initializes, e.g. XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    arr = np.array(devices[:n]).reshape([s for _, s in items])
    return Mesh(arr, tuple(a for a, _ in items))


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Names the mesh axes and the sharding regime for one launch.

    ``mesh_axis_sizes`` carries the mesh extents so shape-dependent
    rules (MoE expert-parallel vs tensor-parallel, FSDP divisibility)
    can be decided without a live mesh.  An empty tuple means "sizes
    unknown": the MoE expert-parallel check passes optimistically
    (a wrong guess only costs efficiency), but FSDP/ZeRO-1 scatter is
    SKIPPED — pjit argument shardings do not pad, so a data-axis shard
    is only placed on a provably divisible dim.  Build policies with
    :meth:`for_mesh` to get both.
    """

    mesh_axis_sizes: Tuple[Tuple[str, int], ...] = ()
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    seq_axis: Axes = None
    fsdp: bool = False
    zero1: bool = False
    # FSDP/ZeRO-1 only scatter tensors with at least this many elements
    # — sharding small norms/biases buys nothing and costs a gather.
    fsdp_min_size: int = 1 << 20

    @classmethod
    def for_mesh(cls, mesh, *, seq_axis: Axes = None,
                 fsdp: bool = False, zero1: bool = False,
                 **overrides) -> "ShardingPolicy":
        """Policy for a ``Mesh`` or an ``AbstractMesh`` — the policy
        only consumes axis names and extents, so an abstract mesh
        description (no devices) decides placement identically."""
        sizes = _mesh_axis_sizes(mesh)
        names = tuple(a for a, _ in sizes)
        data = tuple(a for a in names if a in ("pod", "data")) or names[:1]
        model = "model" if "model" in names else names[-1]
        return cls(mesh_axis_sizes=sizes, data_axes=data, model_axis=model,
                   seq_axis=seq_axis, fsdp=fsdp, zero1=zero1, **overrides)

    # ---- axis arithmetic ---------------------------------------------------
    @property
    def batch_spec(self) -> Axes:
        """PartitionSpec entry for a batch dimension."""
        if len(self.data_axes) == 1:
            return self.data_axes[0]
        return tuple(self.data_axes)

    def axis_size(self, name: str) -> Optional[int]:
        return dict(self.mesh_axis_sizes).get(name)

    @property
    def model_size(self) -> Optional[int]:
        return self.axis_size(self.model_axis)

    @property
    def data_size(self) -> Optional[int]:
        n = 1
        for a in self.data_axes:
            s = self.axis_size(a)
            if s is None:
                return None
            n *= s
        return n

    # ---- paged-cache placement --------------------------------------------
    def page_spec(self, n_pages: int) -> Axes:
        """PartitionSpec entry for the page dimension of a paged-cache
        pool (``[n_pages, page_size, ...]``).

        A page pool has no batch dimension — the page dim *is* the
        capacity dim, so it takes the data axes the contiguous cache put
        on batch.  pjit argument shardings do not pad, so the dim is
        only sharded when provably divisible (mirrors the FSDP rule);
        GSPMD then turns the block-table gather into the cross-device
        page fetch.  Unknown mesh sizes or indivisible pools replicate,
        which always lowers.

        This spec is the *signature* placement of the decode step
        regardless of its attention backend: the gather path's
        block-table indexing partitions natively, while the
        ``pallas_paged`` kernel (an opaque call with no GSPMD
        partitioning rule) has its operands gathered/re-sharded around
        the call — the pool still lives sharded between steps, so page
        residency and donation behave identically on real meshes
        (mesh==solo pinned in ``tests/test_serve_multidevice.py``).
        """
        dsize = self.data_size
        if dsize and dsize > 1 and n_pages % dsize == 0:
            return self.batch_spec
        return None

    def slot_spec(self, n_slots: int) -> Axes:
        """PartitionSpec entry for the *slot* dimension of a paged-cache
        block table (``[n_slots, ...]``).

        Block tables ride the data axes with their slots: under the
        device-local decode layout (:func:`page_spec` pools +
        ``shard_map`` in :func:`repro.serve.engine.build_decode_step`)
        each device holds exactly the table rows of the slots pinned to
        its pool extent, so the decode step needs no block-table
        collective either.  Same divisibility rule as :func:`page_spec`:
        indivisible slot counts replicate, which always lowers.
        """
        dsize = self.data_size
        if dsize and dsize > 1 and n_slots % dsize == 0:
            return self.batch_spec
        return None

    def decode_shards(self, max_batch: int, resident_pages: Optional[int],
                      state_pages: Optional[int]) -> int:
        """Number of device-local pool extents a paged serve cache should
        be built with on this policy's mesh: the data-axis extent when
        slots and both pool sizes split evenly across it (the
        ``shard_map`` decode layout), else 1 (single-pool layout — the
        decode step then falls back to GSPMD, which lowers everywhere
        but gathers the pools).  ``None`` pool sizes are engine defaults
        sized per-slot, hence always divisible when ``max_batch`` is."""
        dsize = self.data_size
        if not dsize or dsize <= 1:
            return 1
        if max_batch % dsize:
            return 1
        if resident_pages is not None and resident_pages % dsize:
            return 1
        if state_pages is not None and state_pages % dsize:
            return 1
        return dsize


def _key(entry) -> str:
    """Stringify one pytree path entry (DictKey/SequenceKey/GetAttrKey)."""
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def _add_fsdp(spec: P, shape: Tuple[int, ...], policy: ShardingPolicy,
              skip_dim0: bool = True) -> P:
    """Shard one free, data-divisible dim of a large tensor over the
    data axes.  ``skip_dim0`` protects the stacked group (scan) dim of
    block parameters; ZeRO-1 passes False for flat optimizer moments."""
    n = 1
    for s in shape:
        n *= int(s)
    if n < policy.fsdp_min_size:
        return spec
    dsize = policy.data_size
    if not dsize:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    if any(a in used for a in policy.data_axes):
        return spec
    for dim in range(1 if skip_dim0 else 0, len(shape)):
        if entries[dim] is None and shape[dim] % dsize == 0:
            entries[dim] = policy.batch_spec
            return P(*entries)
    return spec


def param_specs(shapes, policy: Optional[ShardingPolicy] = None):
    """PartitionSpec pytree for a TransformerLM parameter (shape) tree.

    See the rule table in the :mod:`repro.dist` docstring.  Parameters
    under ``"blocks"`` are stacked over scan groups and keep their
    leading dim unsharded; ``"tail"`` layers are unstacked.
    """
    policy = policy or ShardingPolicy()
    m = policy.model_axis

    def one(path, leaf):
        keys = [_key(e) for e in path]
        top, name = keys[0], keys[-1]
        mod = keys[-2] if len(keys) >= 2 else ""
        nd = len(leaf.shape)
        lead = (None,) if top == "blocks" else ()
        spec = None
        if top == "embed":                       # tok [V, d]
            spec = P(m, None)
        elif top == "lm_head":                   # [d, V]
            spec = P(None, m)
        elif mod == "attn":
            if name in ("wq", "wk", "wv"):       # [d, heads*hd]
                spec = P(*lead, None, m)
            elif name == "wo":                   # [heads*hd, d]
                spec = P(*lead, m, None)
            elif name in ("bq", "bk", "bv"):     # [heads*hd]
                spec = P(*lead, m)
        elif mod == "mlp":
            if name in ("wi", "wg"):             # [d, ff]
                spec = P(*lead, None, m)
            elif name == "wo":                   # [ff, d]
                spec = P(*lead, m, None)
        elif mod == "moe":
            if name in ("wi", "wg", "wo"):       # [E, d, f] / [E, f, d]
                n_storage_experts = leaf.shape[len(lead)]
                msize = policy.model_size
                expert_parallel = (msize is None
                                   or n_storage_experts % msize == 0)
                if expert_parallel:
                    spec = P(*lead, m, None, None)
                elif name == "wo":
                    spec = P(*lead, None, m, None)
                else:
                    spec = P(*lead, None, None, m)
        elif mod == "ssm":
            if name == "in_proj":                # [d, 2*di]
                spec = P(*lead, None, m)
            elif name == "out_proj":             # [di, d]
                spec = P(*lead, m, None)
        elif mod == "rec":
            if name in ("wx", "wgate", "w_a", "w_i"):   # [d|dl, dl]
                spec = P(*lead, None, m)
            elif name == "out_proj":             # [dl, d]
                spec = P(*lead, m, None)
        if spec is None:
            spec = P(*([None] * nd))
        if policy.fsdp:
            spec = _add_fsdp(spec, tuple(leaf.shape), policy,
                             skip_dim0=(top == "blocks"))
        return spec

    return jax.tree_util.tree_map_with_path(one, shapes)


def batch_specs(policy: ShardingPolicy) -> Tuple[P, P]:
    """(token_spec, label_spec) for [batch, seq] training inputs."""
    spec = P(policy.batch_spec, policy.seq_axis)
    return spec, spec
