"""Distribution layer: sharding policy + axis environment.

The package has two halves, split by *when* sharding decisions are
made:

* :mod:`repro.dist.sharding` — **static placement**.
  :class:`~repro.dist.sharding.ShardingPolicy` names the mesh axes
  (data axes, model axis, optional sequence axis) and the FSDP/ZeRO-1
  regime; :func:`~repro.dist.sharding.param_specs` maps a parameter
  pytree (shapes only — works under ``jax.eval_shape``) to a
  ``PartitionSpec`` pytree.

* :mod:`repro.dist.axisenv` — **dynamic constraints**.
  ``with axis_env(policy, mesh=mesh):`` binds logical dimension tags
  to mesh axes inside a traced computation, and
  ``constrain(x, "B", None, "M")`` re-shards intermediates without
  the model code ever naming a concrete mesh axis.

Axis-env semantics
==================

Tags are single letters: ``"B"`` (batch -> the policy's data axes),
``"S"`` (sequence -> the policy's ``seq_axis``, usually ``None``),
``"M"`` (model/tensor-parallel axis), and ``None`` (unsharded).  Tag
resolution *dedups left to right*: a mesh axis consumed by an earlier
dimension is dropped from later tags (a tag whose axes are all taken
resolves to ``None`` rather than producing an invalid spec), so model
code can tag dimensions optimistically — e.g. sequence-sharding over
the whole mesh leaves ``"M"`` empty.  Outside any env (or without a
mesh) ``constrain`` is the identity, which keeps the pure-CPU unit
tests and ``eval_shape`` paths free of device state.

Sharding rule table (``param_specs``)
=====================================

Stacked block parameters carry a leading group (scan) dim that is
never sharded.  ``m`` is the policy's model axis.

==========  =============  ========================================
module      tensor         rule
==========  =============  ========================================
embed       tok [V, d]     ``P(m, None)`` (vocab-sharded)
lm_head     [d, V]         ``P(None, m)``
attn        wq/wk/wv       ``P(..., None, m)`` (head-sharded)
attn        wo             ``P(..., m, None)``
attn        bq/bk/bv       ``P(..., m)``
mlp         wi/wg          ``P(..., None, m)``
mlp         wo             ``P(..., m, None)``
moe         wi/wg/wo       expert-parallel ``P(..., m, None, None)``
                           when the model-axis size divides the
                           storage expert count (virtual split
                           included), else tensor-parallel inside
                           each expert
moe         router         replicated
ssm         in_proj        ``P(..., None, m)``
ssm         out_proj       ``P(..., m, None)``
rec (the    wx/wgate/w_a/  ``P(..., None, m)``
RG-LRU      w_i
block key)  out_proj       ``P(..., m, None)``
norms etc.  *              replicated
==========  =============  ========================================

With ``fsdp=True``, tensors at or above ``fsdp_min_size`` elements
additionally shard one free, data-divisible dimension over the data
axes (never the stacked scan dim); small tensors stay replicated.
ZeRO-1 reuses the same helper (``_add_fsdp``) to scatter replicated
optimizer moments.

Paged-cache placement (``ShardingPolicy.page_spec``)
====================================================

Paged decode-cache pools (``[n_pages, page_size, ...]`` — see
:mod:`repro.serve.paging`) have no batch dimension; the *page* dim is
the capacity dim, so it takes the data axes the contiguous cache put on
batch — but only when the pool page count is provably divisible
(pjit argument shardings do not pad).  KV heads / state channels keep
the model axis per the serving rules in ``repro.serve.engine
.cache_specs``; block tables replicate (tiny int32 indirection state
every device needs to resolve its page gathers).
"""
from repro.dist.axisenv import AxisEnv, axis_env, constrain, current_env
from repro.dist.sharding import ShardingPolicy, batch_specs, param_specs

__all__ = [
    "AxisEnv", "axis_env", "constrain", "current_env",
    "ShardingPolicy", "batch_specs", "param_specs",
]
