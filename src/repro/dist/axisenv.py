"""Axis environment: logical dimension tags -> mesh axes.

Model code tags array dimensions with ``"B"`` / ``"S"`` / ``"M"``
(batch / sequence / model) instead of naming mesh axes; the active
:class:`AxisEnv` — installed by ``with axis_env(...):`` around the
traced computation — resolves tags to the mesh axes of the current
sharding policy.  See the package docstring for the dedup semantics.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["AxisEnv", "axis_env", "current_env", "constrain"]

# A tag target: no sharding, one mesh axis, or several mesh axes.
Axes = Union[None, str, Tuple[str, ...]]

_UNSET = object()


def _tup(axes: Axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


class AxisEnv:
    """Binding of the logical tags to mesh axes (plus the mesh itself).

    ``batch`` / ``seq`` / ``model`` keep their raw form (``None`` means
    "unsharded", which callers test with ``env.seq is not None``).
    """

    def __init__(self, batch: Axes, model: Axes, seq: Axes,
                 mesh: Optional[Mesh]):
        self.batch = batch
        self.model = model
        self.seq = seq
        self.mesh = mesh

    def axes(self, tag: Optional[str]) -> Tuple[str, ...]:
        """Mesh axes a tag resolves to (only axes present on the mesh)."""
        raw = _tup({"B": self.batch, "S": self.seq, "M": self.model,
                    None: None}[tag])
        if self.mesh is None:
            return raw
        return tuple(a for a in raw if a in self.mesh.axis_names)

    def size(self, tag: Optional[str]) -> Optional[int]:
        """Total mesh extent of a tag, or None if unbound/unmeshed."""
        if self.mesh is None:
            return None
        axes = self.axes(tag)
        if not axes:
            return None
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        n = 1
        for a in axes:
            n *= int(sizes[a])
        return n


_LOCAL = threading.local()


def current_env() -> Optional[AxisEnv]:
    """The innermost active env, or None outside any ``axis_env``."""
    stack = getattr(_LOCAL, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def axis_env(policy=None, *, batch_axes: Axes = _UNSET,
             model_axis: Axes = _UNSET, seq_axis: Axes = _UNSET,
             mesh: Optional[Mesh] = None):
    """Install an :class:`AxisEnv` for the dynamic extent of the block.

    Accepts either a :class:`~repro.dist.sharding.ShardingPolicy`
    (positional) or explicit ``batch_axes`` / ``model_axis`` /
    ``seq_axis`` kwargs; explicit kwargs override the policy's fields
    (including an explicit ``None``, which unbinds the tag).
    """
    if policy is not None:
        batch = policy.data_axes if batch_axes is _UNSET else batch_axes
        model = policy.model_axis if model_axis is _UNSET else model_axis
        seq = policy.seq_axis if seq_axis is _UNSET else seq_axis
    else:
        batch = None if batch_axes is _UNSET else batch_axes
        model = None if model_axis is _UNSET else model_axis
        seq = None if seq_axis is _UNSET else seq_axis
    env = AxisEnv(batch, model, seq, mesh)
    stack = getattr(_LOCAL, "stack", None)
    if stack is None:
        stack = _LOCAL.stack = []
    stack.append(env)
    try:
        yield env
    finally:
        stack.pop()


def constrain(x, *tags: Optional[str]):
    """``with_sharding_constraint`` by tag; identity outside any env.

    Each positional tag shards one leading dimension of ``x``
    (trailing dimensions default to unsharded).  Mesh axes are consumed
    left to right: an axis grabbed by an earlier dimension is dropped
    from later tags, and a tag with no axes left resolves to ``None``
    — so repeated tags dedup instead of building an invalid spec.
    """
    env = current_env()
    if env is None or env.mesh is None:
        return x
    used = set()
    entries = []
    for t in tags:
        free = tuple(a for a in env.axes(t) if a not in used)
        used.update(free)
        if not free:
            entries.append(None)
        elif len(free) == 1:
            entries.append(free[0])
        else:
            entries.append(free)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, P(*entries)))
