"""Training loop: jitted sharded steps, AdamW, and a production trainer.

``optimizer.py`` is a self-contained AdamW (+LR schedules, global-norm
clipping) so the repo has no optax dependency; ``step.py`` builds the
jitted train/eval steps (donated optimizer state, gradient
accumulation, `repro.dist` shardings applied to params and batch);
``trainer.py`` wires them into a production loop — checkpoint/restart
through :mod:`repro.checkpoint` (atomic, content-verified), preemption
handling, and elastic re-mesh on restore (a checkpoint written on one
mesh restores onto another via the policy's resharding rules).

Training exists here to exercise the same sharded model/dist stack the
serving path uses — the RTC reproduction itself is inference/energy
focused (see ``docs/ARCHITECTURE.md``), so this package stays small
and dependency-free rather than growing toward a full training
framework.
"""
