"""Train/eval step builders: jitted, sharded, grad-accumulating.

``build_train_step`` returns the canonical production step:
loss -> grad -> global-norm clip -> AdamW, with params/opt-state
sharded per :mod:`repro.dist.sharding` and batch sharded on the data
axes.  ``microbatch`` > 1 folds gradient accumulation *inside* the step
(a ``lax.scan`` over microbatches), which is the memory-term hillclimb
lever for the big train cells.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.axisenv import axis_env
from repro.dist.sharding import ShardingPolicy, batch_specs, param_specs
from repro.models.transformer import TransformerLM
from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update

__all__ = ["TrainState", "build_train_step", "train_state_specs",
           "init_train_state"]


class TrainState(NamedTuple):
    params: dict
    opt: OptState


def init_train_state(model: TransformerLM, key,
                     state_dtype="float32") -> TrainState:
    params = model.init(key)
    return TrainState(params=params,
                      opt=adamw_init(params, state_dtype))


def train_state_specs(model: TransformerLM,
                      policy: ShardingPolicy,
                      state_dtype="float32") -> TrainState:
    """PartitionSpec tree for a TrainState (shapes via eval_shape)."""
    shapes = jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0), state_dtype))
    pspecs = param_specs(shapes.params, policy)
    mspecs = param_specs(shapes.opt.mu, policy)
    if policy.zero1:
        # ZeRO-1: scatter replicated moment tensors across the data
        # axes — on a divisible dim only (pjit argument shardings do
        # not pad), small tensors stay replicated.
        from repro.dist.sharding import _add_fsdp

        def z1(spec, leaf):
            if all(ax is None for ax in spec) and leaf.ndim >= 1:
                return _add_fsdp(spec, tuple(leaf.shape), policy,
                                 skip_dim0=False)
            return spec
        mspecs = jax.tree_util.tree_map(
            z1, mspecs, shapes.opt.mu,
            is_leaf=lambda x: isinstance(x, P))
    return TrainState(
        params=pspecs,
        opt=OptState(step=P(), mu=mspecs, nu=mspecs),
    )


def build_train_step(
    model: TransformerLM,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    policy: ShardingPolicy,
    microbatch: int = 1,
    donate: bool = True,
    input_kind: str = "tokens",
):
    """Returns (step_fn, state_shardings, batch_shardings).

    ``input_kind="embeds"`` trains on precomputed frontend embeddings
    [b, s, d] (the vlm/audio stub path) instead of token ids.
    """
    tok_spec, lab_spec = batch_specs(policy)
    state_dtype = opt_cfg.state_dtype
    if input_kind == "embeds":
        tok_spec = P(*(tuple(tok_spec) + (None,)))

    spec_state = train_state_specs(model, policy, state_dtype)
    _grad_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            spec_state.params,
                            is_leaf=lambda x: isinstance(x, P))

    def shard_grads(grads):
        # Gradients shard exactly like the parameters.
        return jax.lax.with_sharding_constraint(grads, _grad_sh)

    def loss_fn(params, tokens, labels):
        # Re-constraining the params at the top of the loss is a no-op
        # forward, but with_sharding_constraint transposes to itself:
        # each parameter's GRADIENT is forced onto the same sharding at
        # the very start of its backward accumulation.  Without this,
        # GSPMD materialized full unsharded f32 expert-weight grads and
        # all-reduced 11.5 GiB/device operands on mixtral train.
        params = jax.lax.with_sharding_constraint(params, _grad_sh)
        with axis_env(policy, mesh=mesh):
            if input_kind == "embeds":
                return model.loss(params, embeds=tokens, labels=labels)
            return model.loss(params, tokens=tokens, labels=labels)

    def train_step(state: TrainState, tokens, labels):
        if microbatch == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                state.params, tokens, labels)
            grads = shard_grads(grads)
        else:
            b = tokens.shape[0]
            assert b % microbatch == 0
            tks = tokens.reshape((microbatch, b // microbatch)
                                 + tokens.shape[1:])
            lbs = labels.reshape((microbatch, b // microbatch)
                                 + labels.shape[1:])

            def acc_body(carry, xs):
                loss_acc, grad_acc = carry
                t, l = xs
                loss, grads = jax.value_and_grad(loss_fn)(state.params, t, l)
                grads = shard_grads(grads)
                return (loss_acc + loss,
                        shard_grads(jax.tree.map(jnp.add, grad_acc, grads))
                        ), None

            zeros = shard_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), (tks, lbs))
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)

        new_params, new_opt = adamw_update(opt_cfg, state.params, grads,
                                           state.opt)
        return TrainState(new_params, new_opt), loss

    sh = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
    state_sh = sh(spec_state)
    tok_sh, lab_sh = (NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, lab_spec))

    step = jax.jit(
        train_step,
        in_shardings=(state_sh, tok_sh, lab_sh),
        out_shardings=(state_sh, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return step, state_sh, (tok_sh, lab_sh)
