"""Production trainer: checkpoint/restart, preemption, elastic re-mesh.

Fault-tolerance contract (tested in ``tests/test_fault_tolerance.py``):

* **Exact resume** — data is stateless-deterministic (step -> batch) and
  checkpoints capture (params, opt, step), so a killed-and-restarted
  run reproduces the uninterrupted loss trajectory bit-for-bit on CPU.
* **Atomic checkpoints** — a crash mid-save never corrupts the latest
  restorable step (write-tmp-then-rename in ``repro.checkpoint``).
* **Preemption** — SIGTERM sets a flag; the loop checkpoints and exits
  cleanly at the next step boundary (standard TPU-pod eviction hook).
* **Elastic re-mesh** — ``Trainer`` takes the mesh as a constructor
  argument and restores checkpoints onto *whatever* mesh it is given
  (restore reshards leaves), so a job restarted on fewer/more slices
  re-lowers and continues.
* **Straggler/hang mitigation** — ``step_timeout_s`` wraps the blocking
  result fetch; a stalled collective raises instead of hanging the job
  forever (the launcher restarts from the last checkpoint).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import List, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import SyntheticLMData
from repro.dist.sharding import ShardingPolicy
from repro.models.transformer import TransformerLM
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainState, build_train_step, init_train_state

__all__ = ["Trainer", "TrainReport"]


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_step: int
    losses: List[float]
    resumed_from: Optional[int]
    preempted: bool = False


class Trainer:
    def __init__(
        self,
        model: TransformerLM,
        opt_cfg: AdamWConfig,
        mesh,
        policy: ShardingPolicy,
        data: SyntheticLMData,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        microbatch: int = 1,
        step_timeout_s: float = 600.0,
        seed: int = 0,
    ):
        self.model = model
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.policy = policy
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.step_timeout_s = step_timeout_s
        self.seed = seed
        self._preempted = False
        self.step_fn, self.state_sh, self.batch_sh = build_train_step(
            model, opt_cfg, mesh, policy, microbatch=microbatch)

    # -- preemption hook ----------------------------------------------------
    def install_preemption_handler(self):
        signal.signal(signal.SIGTERM, lambda *_: self._flag_preempt())

    def _flag_preempt(self):
        self._preempted = True

    # -- state --------------------------------------------------------------
    def _fresh_state(self) -> TrainState:
        with self.mesh:
            state = jax.jit(
                lambda: init_train_state(self.model, jax.random.key(self.seed)),
                out_shardings=self.state_sh,
            )()
        return state

    def _try_resume(self) -> tuple[TrainState, int, Optional[int]]:
        if self.ckpt_dir:
            latest = store.latest_step(self.ckpt_dir)
            if latest is not None:
                like = jax.eval_shape(
                    lambda: init_train_state(self.model,
                                             jax.random.key(self.seed)))
                state = store.restore(self.ckpt_dir, latest, like,
                                      shardings=self.state_sh)
                return state, latest, latest
        return self._fresh_state(), 0, None

    # -- loop ---------------------------------------------------------------
    def run(self, n_steps: int) -> TrainReport:
        state, start, resumed = self._try_resume()
        losses: List[float] = []
        step = start
        for step in range(start, start + n_steps):
            tokens, labels = self.data.batch_at(step)
            t0 = time.time()
            with self.mesh:
                state, loss = self.step_fn(state, tokens, labels)
            loss = self._fetch(loss)
            if time.time() - t0 > self.step_timeout_s:
                raise TimeoutError(
                    f"step {step} exceeded {self.step_timeout_s}s "
                    "(straggler/hang mitigation)")
            losses.append(float(loss))
            if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                store.save(self.ckpt_dir, step + 1, state,
                           extra={"arch": self.model.cfg.name})
            if self._preempted:
                if self.ckpt_dir:
                    store.save(self.ckpt_dir, step + 1, state,
                               extra={"preempted": True})
                return TrainReport(step + 1 - start, step + 1, losses,
                                   resumed, preempted=True)
        return TrainReport(n_steps, start + n_steps, losses, resumed)

    def _fetch(self, x):
        return np.asarray(jax.block_until_ready(x))
