"""AdamW + LR schedules + global-norm clipping (self-contained).

Optimizer state shards exactly like the parameters (the spec tree is
``tree_map``-broadcast), so model-sharded tensors get sharded moments
for free; with ``ShardingPolicy.zero1`` the train step additionally
scatters DP-replicated moments across the data axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # Moment storage dtype.  "bfloat16" halves optimizer-state memory
    # (update math stays f32); the standard squeeze for 100B-class
    # models on 16 GB/chip parts.
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jnp.ndarray   # [] int32
    mu: dict            # first moment  (f32, shards like params)
    nu: dict            # second moment (f32, shards like params)


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_init(params, state_dtype=jnp.float32) -> OptState:
    if isinstance(state_dtype, str):
        state_dtype = {"float32": jnp.float32,
                       "bfloat16": jnp.bfloat16}[state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[dict, OptState]:
    lr = cosine_schedule(cfg)(state.step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    t = (state.step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        sdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                m.astype(sdt), v.astype(sdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=state.step + 1, mu=new_m, nu=new_v)
