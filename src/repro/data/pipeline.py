"""Deterministic synthetic data pipeline.

Stateless by construction: ``batch_at(step)`` is a pure function of
(seed, step), so checkpoint/restart resumes *exactly* — the
fault-tolerance property the trainer's restart test asserts.  Batches
are produced host-side (numpy) and placed with the train step's input
sharding; a one-deep prefetch overlaps host generation with device
compute.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["SyntheticLMData"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    """Zipf-ish synthetic token stream with next-token labels."""

    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        # Zipf-like marginal over the vocab (heavy head, long tail).
        u = rng.random((self.batch, self.seq_len + 1))
        toks = np.floor(
            (self.vocab_size ** u - 1.0) / (self.vocab_size - 1.0)
            * self.vocab_size
        ).astype(np.int32)
        toks = np.clip(toks, 0, self.vocab_size - 1)
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
