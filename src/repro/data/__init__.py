"""Deterministic synthetic data pipeline.

``pipeline.py`` generates seeded synthetic token batches with
production-pipeline *shape*: sharded per-host batches, deterministic
resume from a step counter (no stored iterator state), and a schema
matching what :mod:`repro.train`'s steps consume.  Synthetic-only is a
deliberate scope choice — the reproduction's subject is serving-time
DRAM traffic and refresh energy (see ``docs/ARCHITECTURE.md``), so the
data layer provides determinism for tests and benchmarks rather than
real corpora.
"""
