"""Serving telemetry: account engine DRAM traffic for the RTC engine.

The paper's closing argument is that RTC applies to any workload whose
DRAM access pattern stays predictable over a retention window — an LM
decode loop is exactly that (every step re-streams the active weights
and sweeps the KV cache in order).  This module closes the loop between
the serving stack and the energy model: the engine reports *events*
(one prefill of ``plen`` tokens; one decode step over live contexts),
:class:`TrafficModel` converts them to bytes for a target deployment
config, and :meth:`ServeTelemetry.workload_profile` folds the result
into a :class:`repro.core.workload.WorkloadProfile` that
``repro.core.rtc.evaluate`` / ``repro.core.refresh_sim.simulate``
consume directly.

Splitting events from byte constants means the *scheduling trace* can
come from a real (smoke-scale) engine run while the *byte magnitudes*
come from the full-size deployment config — the traffic pattern is
measured, not hand-built, and the energy numbers still describe the
production model.

Paged serving adds a third traffic class: page-out/page-in events
(host offload of a preempted slot's cache pages and their restore —
:mod:`repro.serve.paging`) convert to whole-page bytes via
:meth:`TrafficModel.page_bytes` and join the profile as extra DRAM
reads/writes.  All byte accumulators are exact ints, so the invariant
"summed per-event bytes == profile x decode steps" holds bit-for-bit
(test-pinned in ``tests/test_paged_cache.py``).

Decode-backend awareness (PR 5): how a paged step's KV bytes move
depends on how attention resolves the block tables, and the engine
reports its backend through :meth:`ServeTelemetry.configure_decode`:

* ``gather`` — the jnp path *materializes* the contiguous logical view
  each step: every block-table page is read and a full cache-length
  copy is written per attention layer per live slot, **regardless of
  context occupancy**, before attention even sweeps the view.  That
  phantom traffic (:meth:`TrafficModel.gather_view_read_bytes` /
  ``gather_view_write_bytes``) is exactly the avoidable copy the
  paper's access-management argument targets, and it is accounted so
  the RTC number sees it.
* ``pallas_paged`` — the kernel reads pages in place: the KV sweep is
  ``ceil(ctx/page_size)`` whole pages per layer
  (:meth:`TrafficModel.kv_page_read_bytes`) and nothing else — no
  materialized-view traffic, which is the point of the kernel.
* ``contiguous`` (no paging) — row-exact sweep of the live context,
  unchanged from the seed accounting.

Prefix sharing (PR 10) adds a fourth class.  When admission attaches
registry pages instead of scattering fresh content
(:mod:`repro.serve.paging`), :meth:`ServeTelemetry.record_admit_shared`
splits the admission's KV bytes into *hit* (layer-tokens served by
already-resident shared pages — admission work avoided) and *written*
(the novel remainder plus the always-private recurrent state), with the
exact-int invariant ``hit + written == unshared total`` per admission
(test-pinned in ``tests/test_prefix_sharing.py``).  Copy-on-write forks
are the only device traffic sharing *adds*: each fork bills one page
read + one page write (:meth:`ServeTelemetry.record_cow`), and those
``cow`` bytes join the workload profile's KV streams so the RTC number
never flatters sharing.  Hit bytes stay *out* of the profile: the
dedup-attach admission still physically scatters its redundant rows
into the DUMP page, so the saving is realized as a smaller live row set
(the trace/placement path bills it), while full-skip admissions avoid
the prefill compute outright and are counted in
``prefix_full_skips``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.workload import WorkloadProfile, from_decode
from repro.models.config import ModelConfig

__all__ = ["TrafficModel", "ServeTelemetry"]

_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Per-event DRAM byte constants of one model deployment.

    ``kv_caps`` / ``kv_token_bytes`` carry one entry per attention layer
    (cache slots, K+V bytes per cached token); recurrent (ssm/rglru)
    layers contribute ``state_bytes`` of O(1) per-slot state that is
    read *and* written every step.  ``page_size`` (tokens per KV page,
    0 = contiguous cache) makes offload traffic page-granular: a slot's
    pages cover its context rounded up per layer, exactly what the
    engine moves on preemption.
    """

    param_bytes: int            # resident weight bytes (footprint share)
    param_read_bytes: int       # active weight bytes streamed per step
    kv_caps: Tuple[int, ...]
    kv_token_bytes: Tuple[int, ...]
    state_bytes: int
    page_size: int = 0

    @classmethod
    def from_config(cls, cfg: ModelConfig, max_len: int,
                    page_size: int = 0) -> "TrafficModel":
        itemsize = _ITEMSIZE[cfg.dtype]
        counts = cfg.param_counts()
        caps, bpt = [], []
        state = 0
        for i in range(cfg.n_layers):
            kind = cfg.layer_kind(i)
            if kind in ("global", "local"):
                caps.append(cfg.decode_cache_len(kind, max_len))
                bpt.append(2 * cfg.n_kv_heads * cfg.resolved_head_dim
                           * itemsize)
            elif kind == "ssm":
                state += ((cfg.ssm_conv - 1) * cfg.d_inner * itemsize
                          + cfg.d_inner * cfg.ssm_state * 4)   # h is f32
            elif kind == "rglru":
                dl = cfg.resolved_lru_width
                state += (cfg.conv1d_width - 1) * dl * itemsize + dl * 4
        return cls(
            param_bytes=counts["total"] * itemsize,
            param_read_bytes=cfg.active_param_counts() * itemsize,
            kv_caps=tuple(caps),
            kv_token_bytes=tuple(bpt),
            state_bytes=state,
            page_size=int(page_size),
        )

    # ------------------------------------------------------------ per event
    @property
    def cache_slot_bytes(self) -> int:
        """Allocated decode-cache bytes per batch slot."""
        return sum(c * b for c, b in zip(self.kv_caps, self.kv_token_bytes)) \
            + self.state_bytes

    def kv_read_bytes(self, ctx: int) -> int:
        """KV bytes one slot with ``ctx`` cached tokens reads per step."""
        return sum(min(ctx, c) * b
                   for c, b in zip(self.kv_caps, self.kv_token_bytes))

    @property
    def kv_page_token_bytes(self) -> int:
        """K+V bytes of ONE cached token in ONE attention layer —
        uniform across layers (KV heads and head_dim do not vary per
        layer), so it is the single conversion constant for the
        prefix-sharing *layer-token* accounting
        (:meth:`ServeTelemetry.record_admit_shared`)."""
        return self.kv_token_bytes[0] if self.kv_token_bytes else 0

    @property
    def kv_write_bytes(self) -> int:
        """KV bytes one slot appends per step (one token per layer)."""
        return sum(self.kv_token_bytes)

    def page_bytes(self, ctx: int) -> int:
        """Bytes one offload/restore of a ``ctx``-token slot moves:
        every layer's resident pages (context rounded up to whole pages,
        capped at the layer's cache length) plus the recurrent state
        pages.  With ``page_size == 0`` the move is row-exact."""
        p = self.page_size
        total = self.state_bytes
        for c, b in zip(self.kv_caps, self.kv_token_bytes):
            rows = min(ctx, c)
            if p:
                rows = -(-rows // p) * p
            total += rows * b
        return total

    def kv_page_read_bytes(self, ctx: int) -> int:
        """KV bytes one slot's *kernel* decode step reads: whole pages
        covering the live context per layer (the block-table index map
        DMAs page granules; the partial tail page still streams its
        full ``page_size`` rows).  Row-exact when ``page_size == 0``."""
        p = self.page_size
        if not p:
            return self.kv_read_bytes(ctx)
        return sum((-(-min(ctx, c) // p) * p) * b
                   for c, b in zip(self.kv_caps, self.kv_token_bytes))

    @property
    def gather_view_read_bytes(self) -> int:
        """Pool bytes one slot's *gather* decode step reads to
        materialize the logical view: every block-table page of every
        attention layer — ``ceil(cache_len/page_size)`` full pages —
        independent of how much context is actually live."""
        p = self.page_size
        if not p:
            return sum(c * b for c, b in
                       zip(self.kv_caps, self.kv_token_bytes))
        return sum((-(-c // p) * p) * b
                   for c, b in zip(self.kv_caps, self.kv_token_bytes))

    @property
    def gather_view_write_bytes(self) -> int:
        """Bytes the materialized contiguous view costs to write per
        slot per gather step.  The lowered computation gathers *whole
        pages* — ``ceil(cache_len/page_size) * page_size`` rows per
        layer — and only then slices to the logical cache length, so
        the written copy is page-granular (the jaxpr-level accounting
        the static auditor cross-checks; the previous row-sliced count
        under-billed the tail page)."""
        p = self.page_size
        if not p:
            return sum(c * b for c, b in
                       zip(self.kv_caps, self.kv_token_bytes))
        return sum((-(-c // p) * p) * b
                   for c, b in zip(self.kv_caps, self.kv_token_bytes))

    # -------------------------------------------------- per-class breakdown
    #: Traffic classes of one decode step, the shared vocabulary of
    #: telemetry and the jaxpr-level auditor (``repro.analysis``).
    DECODE_CLASSES = ("kv_sweep_read", "kv_page_read", "kv_append_write",
                      "state_read", "state_write",
                      "gather_view_read", "gather_view_write")

    def static_decode_classes(self, ctx_lengths: Sequence[int],
                              mode: str) -> dict:
        """Exact per-class bytes of ONE decode step over live slots with
        the given context lengths, keyed by :attr:`DECODE_CLASSES`.

        This is the analytic twin of the static traffic auditor: at
        full occupancy (every slot at its layer cache length) the
        structural byte count of the lowered decode step equals this
        breakdown class-for-class, which ``repro.analysis`` asserts.
        :meth:`ServeTelemetry.record_decode` accumulates through the
        same method, so the runtime accounting cannot drift from the
        statically-verified one.
        """
        live = len(ctx_lengths)
        cls = {k: 0 for k in self.DECODE_CLASSES}
        cls["state_read"] = self.state_bytes * live
        cls["state_write"] = self.state_bytes * live
        cls["kv_append_write"] = self.kv_write_bytes * live
        if mode == "pallas_paged":
            cls["kv_page_read"] = sum(self.kv_page_read_bytes(c)
                                      for c in ctx_lengths)
        else:
            cls["kv_sweep_read"] = sum(self.kv_read_bytes(c)
                                       for c in ctx_lengths)
        if mode == "gather":
            cls["gather_view_read"] = self.gather_view_read_bytes * live
            cls["gather_view_write"] = self.gather_view_write_bytes * live
        return cls


class ServeTelemetry:
    """Accumulates engine events and emits the RTC workload profile.

    ``ctx_scale`` linearly extrapolates the recorded per-slot context
    lengths before byte conversion (each layer still caps at its cache
    length).  Use it when the scheduling trace comes from a downsized
    engine (e.g. a CPU smoke run with ``max_len=32``) but the profile
    should describe a deployment context: ``ctx_scale = serve_ctx /
    engine.max_len`` maps the measured occupancy shape onto the target
    context without hand-building the traffic.

    ``decode_mode`` — how decode-step KV bytes are converted:
    ``"contiguous"`` (row-exact sweep of the live context),
    ``"gather"`` (adds the paged gather path's materialized-view
    traffic), or ``"pallas_paged"`` (whole-page reads only — the
    kernel path never materializes a view).  ``None`` (default) lets
    the engine set it via :meth:`configure_decode` at serve time;
    passing an explicit mode pins it (the engine will not override).

    ``trace`` — an optional :class:`repro.core.trace.PageAccessTrace`
    the engine appends per-step page accesses to (paged engines only;
    the engine validates the stream binding at serve time).  Telemetry
    itself never reads it: it is the hand-off point between the serving
    loop and the trace-driven refresh simulation
    (:func:`repro.core.refresh_sim.simulate_trace`).
    """

    _MODES = ("contiguous", "gather", "pallas_paged")

    def __init__(self, traffic: TrafficModel, ctx_scale: float = 1.0,
                 decode_mode: Optional[str] = None, trace=None):
        if decode_mode is not None and decode_mode not in self._MODES:
            raise ValueError(
                f"decode_mode must be one of {self._MODES}, "
                f"got {decode_mode!r}")
        self.trace = trace
        self.traffic = traffic
        self.ctx_scale = float(ctx_scale)
        self._pinned_mode = decode_mode is not None
        self.decode_mode = decode_mode or "contiguous"
        self.n_prefills = 0
        self.prefill_tokens = 0         # TRUE prompt tokens prefetched
        self.prefill_padded_tokens = 0  # positions incl. bucket padding
        self.prefill_time_s = 0.0
        self.decode_steps = 0
        self.decode_time_s = 0.0
        self.tokens_generated = 0
        self.max_live = 0
        self.page_outs = 0             # slot offloads (device -> host)
        self.page_ins = 0              # slot restores (host -> device)
        # Byte totals are kept as exact ints so the invariant
        # "sum(per-event bytes) == profile * decode_steps" is testable
        # bit-for-bit (floats would round on the way in).
        self.param_read_bytes_total = 0  # active weights streamed per step
        self.kv_read_bytes_total = 0     # KV sweeps + recurrent state reads
        self.write_bytes_total = 0       # KV appends + recurrent state writes
        self.page_out_bytes_total = 0    # offloaded page bytes (DRAM reads)
        self.page_in_bytes_total = 0     # restored page bytes (DRAM writes)
        self.gather_read_bytes_total = 0   # phantom view gathers (reads)
        self.gather_write_bytes_total = 0  # phantom view copies (writes)
        # Prefix-sharing accounting (all zero unless the engine serves
        # with PrefixSharingConfig): per-admission hit/written split
        # plus the copy-on-write fork traffic — the only bytes sharing
        # ADDS to the device.
        self.prefix_admits = 0           # admissions that touched keys
        self.prefix_full_skips = 0       # whole-prompt memo admissions
        self.prefix_suffix_feeds = 0     # opt-in suffix-feed admissions
        self.prefix_hit_tokens = 0       # layer-tokens attached, not written
        self.prefix_hit_bytes_total = 0    # hit layer-tokens as KV bytes
        self.admit_write_bytes_total = 0   # novel admission KV+state bytes
        self.cow_read_bytes_total = 0      # fork page copies (DRAM reads)
        self.cow_write_bytes_total = 0     # fork page copies (DRAM writes)

    def configure_decode(self, backend: str, paged: bool) -> None:
        """Engine hook: map its (decode_backend, paged?) pair onto the
        accounting mode.  A mode passed to the constructor is pinned
        and wins; otherwise contiguous engines are row-exact and paged
        engines account their backend's real traffic."""
        if self._pinned_mode:
            return
        self.decode_mode = backend if paged else "contiguous"

    # ------------------------------------------------------------- recording
    def record_prefill(self, plen: int, dt: float = 0.0,
                       padded_len: Optional[int] = None) -> None:
        """One prefill of ``plen`` TRUE prompt tokens.

        ``padded_len``: the bucket size actually lowered (>= plen) when
        the engine length-buckets prefill.  Traffic and the RTC profile
        are always accounted from ``plen`` — padding is compute the
        model masks out, not DRAM-resident prompt state — while the
        padded total is kept so the pad overhead stays visible
        (:attr:`prefill_pad_waste`).
        """
        self.n_prefills += 1
        self.prefill_tokens += int(plen)
        self.prefill_padded_tokens += int(plen if padded_len is None
                                          else padded_len)
        self.prefill_time_s += dt
        self.tokens_generated += 1   # first token samples off prefill logits

    @property
    def prefill_pad_waste(self) -> float:
        """Fraction of prefilled positions that were bucket padding."""
        if not self.prefill_padded_tokens:
            return 0.0
        return 1.0 - self.prefill_tokens / self.prefill_padded_tokens

    def record_decode(self, ctx_lengths: Sequence[int], dt: float = 0.0) -> None:
        """One batched decode step over live slots with the given
        per-slot context lengths (cached tokens attended).

        KV bytes follow :attr:`decode_mode`: the kernel path reads
        whole pages covering each live context and nothing more; the
        gather path additionally pays the materialized logical view
        (full block-table read + contiguous copy per layer per slot)
        on top of its row-exact attention sweep."""
        t = self.traffic
        live = len(ctx_lengths)
        self.decode_steps += 1
        self.decode_time_s += dt
        self.tokens_generated += live
        self.max_live = max(self.max_live, live)
        self.param_read_bytes_total += t.param_read_bytes
        # one source of truth: the same per-class breakdown the static
        # auditor (repro.analysis) verifies against the lowered jaxpr
        cls = t.static_decode_classes(
            [self._scaled(c) for c in ctx_lengths], self.decode_mode)
        self.kv_read_bytes_total += (cls["state_read"]
                                     + cls["kv_sweep_read"]
                                     + cls["kv_page_read"])
        self.write_bytes_total += (cls["kv_append_write"]
                                   + cls["state_write"])
        self.gather_read_bytes_total += cls["gather_view_read"]
        self.gather_write_bytes_total += cls["gather_view_write"]

    def _scaled(self, ctx: int) -> int:
        return int(round(ctx * self.ctx_scale))

    def record_admit_shared(self, plen: int, hit_layer_tokens: int,
                            total_layer_tokens: int,
                            skipped_prefill: bool = False,
                            suffix_feed: bool = False) -> None:
        """One prefix-aware admission, split hit vs written.

        ``hit_layer_tokens`` — (layer, token) cells served by attaching
        already-resident shared pages; ``total_layer_tokens`` — the
        cells the same admission writes without sharing (the
        :attr:`PageTable.last_admit <repro.serve.paging.PageTable>`
        pair).  Bytes are exact ints off
        :attr:`TrafficModel.kv_page_token_bytes`, and per admission
        ``hit_bytes + written_bytes == total_layer_tokens *
        kv_page_token_bytes + state_bytes`` — the unshared admission
        total — holds by construction (the exact-sum invariant the
        tests pin; recurrent state is always written, never shared).

        ``skipped_prefill`` marks a full-prompt memo admission: no
        prefill executable ran, but the request still emits its first
        token off the memoized logits, so it accounts as one prefill
        event of ``plen`` true tokens with zero pad waste.
        ``suffix_feed`` marks the opt-in teacher-forced path (its novel
        suffix bills as ordinary decode steps, so only the attached
        prefix appears here)."""
        bpt = self.traffic.kv_page_token_bytes
        hit = int(hit_layer_tokens)
        total = int(total_layer_tokens)
        if hit > total:
            raise ValueError(
                f"record_admit_shared: hit_layer_tokens={hit} exceeds "
                f"total_layer_tokens={total}")
        self.prefix_admits += 1
        self.prefix_hit_tokens += hit
        self.prefix_hit_bytes_total += hit * bpt
        self.admit_write_bytes_total += ((total - hit) * bpt
                                         + self.traffic.state_bytes)
        if skipped_prefill:
            self.prefix_full_skips += 1
            self.n_prefills += 1
            self.prefill_tokens += int(plen)
            self.prefill_padded_tokens += int(plen)
            self.tokens_generated += 1
        if suffix_feed:
            self.prefix_suffix_feeds += 1

    def record_cow(self, layer_tokens: int) -> None:
        """One copy-on-write fork: ``layer_tokens`` (layer, token)
        cells copied device-side from the shared page into the private
        one — one whole-page read plus one whole-page write, unscaled
        (a fork moves exactly one page per stream at any context
        scale)."""
        b = int(layer_tokens) * self.traffic.kv_page_token_bytes
        self.cow_read_bytes_total += b
        self.cow_write_bytes_total += b

    @property
    def prefix_hit_frac(self) -> float:
        """Fraction of prefix-aware admission bytes served by shared
        pages (0.0 when sharing never engaged)."""
        denom = self.prefix_hit_bytes_total + self.admit_write_bytes_total
        if not denom:
            return 0.0
        return self.prefix_hit_bytes_total / denom

    def record_page_out(self, ctx: int) -> None:
        """One slot offload: its resident pages (a ``ctx``-token context)
        leave device DRAM for host memory."""
        self.page_outs += 1
        self.page_out_bytes_total += self.traffic.page_bytes(self._scaled(ctx))

    def record_page_in(self, ctx: int) -> None:
        """One slot restore: the offloaded pages stream back in."""
        self.page_ins += 1
        self.page_in_bytes_total += self.traffic.page_bytes(self._scaled(ctx))

    # ------------------------------------------------------------- reporting
    @property
    def decode_tok_per_s(self) -> float:
        if self.decode_time_s <= 0:
            return 0.0
        return (self.tokens_generated - self.n_prefills) / self.decode_time_s

    def workload_profile(self, name: str = "serve",
                         step_period_s: Optional[float] = None,
                         row_utilization: float = 1.0) -> WorkloadProfile:
        """Fold the recorded decode traffic into a `WorkloadProfile`.

        One profile iteration == one *mean* decode step of the recorded
        trace.  ``step_period_s`` overrides the measured mean step wall
        time (e.g. with a dry-run roofline bound when the trace was
        collected on a smoke model).
        """
        if self.decode_steps == 0:
            raise ValueError("no decode steps recorded")
        n = self.decode_steps
        period = step_period_s if step_period_s is not None \
            else self.decode_time_s / n
        if period <= 0:
            raise ValueError("step period must be positive")
        footprint = self.traffic.param_bytes \
            + self.max_live * self.traffic.cache_slot_bytes
        # gather-mode phantom traffic folds into the KV read/write
        # streams (the view copy moves through the same DRAM rows the
        # KV sweep walks); the split stays visible in the accumulators.
        # copy-on-write fork copies ride the KV streams too: sharing's
        # only added device traffic must reach the RTC number (hit
        # bytes do NOT join — dedup-attach realizes its saving through
        # the smaller live row set the trace/placement path bills)
        return from_decode(
            name,
            param_read_bytes=self.param_read_bytes_total / n,
            kv_read_bytes=(self.kv_read_bytes_total
                           + self.gather_read_bytes_total
                           + self.cow_read_bytes_total) / n,
            kv_write_bytes=(self.write_bytes_total
                            + self.gather_write_bytes_total
                            + self.cow_write_bytes_total) / n,
            page_out_bytes=self.page_out_bytes_total / n,
            page_in_bytes=self.page_in_bytes_total / n,
            footprint_bytes=footprint,
            step_period_s=period,
            row_utilization=row_utilization,
        )
