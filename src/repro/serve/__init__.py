"""Serving stack: sharded step builders, the continuous-batching engine,
and RTC traffic telemetry.

``engine`` owns the compute (length-bucketed masked prefill, per-slot-
position decode, unified per-request sampling); ``telemetry`` owns the
accounting (engine events -> DRAM bytes ->
:class:`repro.core.workload.WorkloadProfile`), which is how serving
traffic reaches the paper's RTC policy engine.
"""
from repro.serve.engine import (PrefillBuckets, Request, ServeEngine,
                                build_decode_step, build_prefill_step,
                                cache_specs)
from repro.serve.telemetry import ServeTelemetry, TrafficModel

__all__ = ["PrefillBuckets", "Request", "ServeEngine", "build_decode_step",
           "build_prefill_step", "cache_specs", "ServeTelemetry",
           "TrafficModel"]
