"""Serving stack: sharded step builders, the continuous-batching engine,
paged cache management, and RTC traffic telemetry.

``engine`` owns the compute (length-bucketed masked prefill, per-slot-
position decode, unified per-request sampling); ``paging`` owns the
cache residency (block tables, page pools, host offload); ``telemetry``
owns the accounting (engine events -> DRAM bytes ->
:class:`repro.core.workload.WorkloadProfile`), which is how serving
traffic reaches the paper's RTC policy engine.

Paged-cache design note (PR 4)
------------------------------
The contiguous engine gave every batch slot a ``max_len``-row cache
allocation for its whole lifetime — long contexts were rejected at
admission and cold KV occupied hot accelerator memory.  The paged mode
(``ServeEngine(..., paged=PagedCacheConfig(...))``) replaces that with
block-table paging, chosen as follows:

* **Page size** — ``page_size`` tokens of K+V per attention layer (one
  pool per attention pattern position; recurrent ssm/rglru state and
  conv tails are one *state page* per slot in mirror pools, so all 10
  architectures go through one :class:`~repro.serve.paging.PageTable`).
  A page is simultaneously the allocation quantum, the offload-transfer
  quantum, and — for ``rtc.evaluate`` — the DRAM-row mapping quantum
  (PENDRAM's point: how logical rows land on physical rows is a policy
  axis; the page table is that policy made explicit).  Logical layouts
  equal the contiguous cache's ring/append order, so paged decode is
  *bit-identical* to contiguous decode (pinned across all 10 archs in
  ``tests/test_paged_cache.py``).
* **Capacity vs. residency** — a slot's logical capacity is ``max_ctx``
  (may exceed ``max_len``: decode grows the slot's page list
  allocate-on-write, so prompt+generation can outlive the old
  contiguous cap), while device residency is bounded by
  ``resident_pages`` per KV stream.
* **Eviction policy** — when a pool runs dry the engine preempts the
  *newest* live request (highest request id; the oldest admitted slot
  is only victimized by its own elders, which preserves FCFS progress),
  offloads its pages to host memory via ``jax.device_put``, and resumes
  it FIFO — before any new admission — once a slot and pages free up.
  Restores are bit-exact: pages re-enter different physical pool pages,
  the block table re-targets, content and the continued generation are
  unchanged.
* **Offload traffic accounting** — every offload/restore is a telemetry
  event (``record_page_out`` / ``record_page_in``); whole-page bytes
  (context rounded up per layer, plus state pages) join weight/KV/state
  traffic in ``workload.from_decode`` as extra DRAM reads/writes, so
  the RTC savings number sees exactly the traffic the refresh model
  cares about.  The invariant "summed per-event bytes == profile x
  steps" is pinned in ``tests/test_paged_cache.py``.
* **Decode backend** (PR 5) — ``ServeEngine(decode_backend=
  "pallas_paged")`` swaps the gather path (materialize the contiguous
  logical view each step) for the block-table Pallas kernel
  (:mod:`repro.kernels.paged_attention` — design note in the
  ``repro.kernels`` package docstring) that reads K/V pages in place.
  Generations are identical either way; telemetry accounts the gather
  path's phantom view traffic and the kernel path's true per-page
  reads, which is where the RTC energy delta between the two shows up.

Prefix-sharing / copy-on-write design note (PR 10)
--------------------------------------------------
``PagedCacheConfig(sharing=PrefixSharingConfig(...))`` turns the page
table content-addressed, ROMANet-style reuse applied at the serving
layer (ROADMAP item 2): identical prompt prefixes map to the *same*
physical KV pages, so N same-prefix requests allocate the prefix once.

* **Hash scheme** — vLLM-style chained content hashing
  (:func:`~repro.serve.paging.prefix_page_keys`): page ``j``'s key is
  ``sha1(key_{j-1} || tokens[jP:(j+1)P])`` seeded with a version tag,
  so a page's identity covers its whole prefix, not just its own
  tokens; a ragged tail gets a ``tail``-salted key and the whole-prompt
  key addresses the full-skip memo.  Keys are per stream and per PR 8
  shard — registries live inside each stream's per-shard extent, so
  sharing never crosses a device boundary.
* **Refcount lifecycle** — a keyed page registers at admission with
  refcount 1; a later admission whose page key is already registered
  *attaches* (refcount += 1) instead of allocating, and its prefill
  scatter is redirected to the DUMP row (the compute still runs — that
  is what keeps shared serving bit-identical; the saving is the page
  row set, which telemetry books as the ``prefix_hit`` class and the
  trace path sees as per-step page-id dedup).  Release/offload
  decrement; the page frees and unregisters at zero.  Sharing is
  in-flight only: no pages outlive their last referencing request.
* **Fork-on-write rules** — decode's ``prepare_step`` never appends
  into a page the slot holds a *shared* reference to: refcount > 1
  forks (allocate + on-device page copy + block-table retarget +
  decref), refcount == 1 unregisters in place and writes through.
  Fork allocation failure feeds the existing preempt/retry path, and
  the sole-live-slot deadlock bound is preserved (a lone slot's refs
  are all its own, so it never needs a fork page).  Recurrent *state*
  pages are rewritten every step and therefore never shared.
* **Scheduler policy** — ``schedule="prefix"`` groups the admission
  queue by whole-prefix group key (first-arrival group order, so no
  starvation) to co-schedule same-prefix requests while their pages
  are live; generations are bit-independent of the schedule because
  sampling keys are (request id, token index)-addressed.
* **Full skip & suffix feed** — an exact whole-prompt hit on the
  bounded memo skips prefill entirely (attach every page, restore the
  host state snapshot, replay the memoized logits — bit-exact).  The
  partial-prefix variant (``suffix_feed=True``) attaches the shared
  full pages and feeds only the suffix through decode; it is opt-in
  because prefill and decode-chain logits differ at float tolerance
  (~1e-6), breaking the default bit-identity pin.
"""
from repro.serve.engine import (PrefillBuckets, Request, ServeEngine,
                                build_decode_step, build_prefill_step,
                                cache_specs)
from repro.serve.paging import (PagedCacheConfig, PageTable, PrefixKeys,
                                PrefixSharingConfig, logical_view,
                                prefix_page_keys)
from repro.serve.telemetry import ServeTelemetry, TrafficModel

__all__ = ["PrefillBuckets", "Request", "ServeEngine", "build_decode_step",
           "build_prefill_step", "cache_specs", "PagedCacheConfig",
           "PageTable", "PrefixKeys", "PrefixSharingConfig", "logical_view",
           "prefix_page_keys", "ServeTelemetry", "TrafficModel"]
