"""Serving: sharded prefill/decode step builders + a batched engine.

``build_prefill_step`` / ``build_decode_step`` produce the exact
computations the inference dry-run shapes lower (`prefill_32k` lowers
the full-sequence forward; `decode_32k` / `long_500k` lower ONE decode
step against a materialized KV cache, per the assignment).

Cache sharding: batch on the data axes, heads/state channels on
``model``; for single-sequence long-context (`long_500k`, batch=1) the
policy's ``kv_seq_axis`` shards the cache *length* instead, which GSPMD
turns into flash-decode-style distributed attention.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.axisenv import axis_env
from repro.dist.sharding import ShardingPolicy, param_specs
from repro.models.config import ModelConfig
from repro.models.transformer import TransformerLM

__all__ = ["cache_specs", "build_prefill_step", "build_decode_step",
           "ServeEngine"]


def cache_specs(model: TransformerLM, batch: int, cache_len: int,
                policy: ShardingPolicy, kv_seq_axis=None,
                model_axis_size: Optional[int] = None):
    """PartitionSpec tree matching ``model.init_cache(batch, cache_len)``.

    KV placement mirrors ``attention.attn_decode``: shard heads on the
    model axis when there are enough KV heads to fill it, otherwise
    shard the cache length (flash-decode).  ``kv_seq_axis`` overrides
    (long_500k shards the length over the whole mesh).
    """
    cfg = model.cfg
    b = policy.batch_spec if batch > 1 else None
    m = policy.model_axis
    shapes = jax.eval_shape(lambda: model.init_cache(batch, cache_len))
    heads_fit = (model_axis_size is not None and cfg.n_kv_heads > 0
                 and cfg.n_kv_heads % model_axis_size == 0)

    def one(path, leaf):
        name = str(getattr(path[-1], "name", getattr(path[-1], "key", "")))
        nd = len(leaf.shape)
        # "groups" caches carry a leading stacked-group axis; "tail"
        # caches (pattern remainder layers) do not.
        top = str(getattr(path[0], "key", ""))
        lead = (None,) if top == "groups" else ()
        if name in ("k", "v"):            # [(G,) B, L, KV, hd]
            if kv_seq_axis is not None:
                return P(*lead, b, kv_seq_axis, None, None)
            if heads_fit:
                return P(*lead, b, None, m, None)
            return P(*lead, b, m, None, None)
        if name == "length":
            return P(*([None] * nd))
        if name == "conv":                 # [(G,) B, k-1, width]
            return P(*lead, b, None, m)
        if name == "h":
            if nd == len(lead) + 3:        # ssm: [(G,) B, di, n]
                return P(*lead, b, m, None)
            return P(*lead, b, m)          # rglru: [(G,) B, dl]
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, shapes)


def build_prefill_step(model: TransformerLM, mesh: Mesh,
                       policy: ShardingPolicy, donate: bool = False,
                       last_only: bool = True):
    """Full-sequence forward with sharded params/batch.

    ``last_only`` (production default): unembed only the final position
    — serving prefill needs the first sampled token, not [b, s, vocab]
    logits (4.2 GiB/device of pure output for gemma2-9b @32k).
    """
    pspecs = param_specs(jax.eval_shape(
        lambda: model.init(jax.random.key(0))), policy)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(mesh, P(policy.batch_spec, policy.seq_axis))

    def prefill(params, tokens):
        with axis_env(policy, mesh=mesh):
            if last_only:
                hidden, _ = model.hidden(params, tokens=tokens)
                return model._unembed(params, hidden[:, -1:])
            logits, _ = model.apply(params, tokens=tokens)
            return logits

    return jax.jit(prefill, in_shardings=(psh, tok_sh)), psh, tok_sh


def build_decode_step(model: TransformerLM, mesh: Mesh,
                      policy: ShardingPolicy, batch: int, cache_len: int,
                      kv_seq_axis=None):
    """One-token decode with sharded KV cache. Returns
    (step_fn, param_shardings, cache_shardings)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = param_specs(jax.eval_shape(
        lambda: model.init(jax.random.key(0))), policy)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    cspecs = cache_specs(model, batch, cache_len, policy, kv_seq_axis,
                         model_axis_size=sizes.get(policy.model_axis))
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(
        mesh, P(policy.batch_spec if batch > 1 else None))

    def decode(params, cache, token, pos):
        seq_override = kv_seq_axis if kv_seq_axis is not None else policy.seq_axis
        with axis_env(batch_axes=policy.data_axes if batch > 1 else None,
                      model_axis=policy.model_axis,
                      seq_axis=seq_override, mesh=mesh):
            return model.decode_step(params, cache, token, pos)

    step = jax.jit(
        decode,
        in_shardings=(psh, csh, tok_sh, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(
            policy.batch_spec if batch > 1 else None, None)), csh),
        donate_argnums=(1,),
    )
    return step, psh, csh


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched serving loop (example / integration tests)."""

    model: TransformerLM
    params: dict
    max_len: int = 256

    def generate(self, prompts: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompts: [b, prompt_len] int32 -> [b, n_new] int32."""
        b, plen = prompts.shape
        cache = self.model.init_cache(b, self.max_len)
        decode = jax.jit(self.model.decode_step)
        tok = None
        # prefill token-by-token through the decode path (engine-level
        # simplicity; the sharded builders above lower true prefill).
        for t in range(plen):
            logits, cache = decode(self.params, cache,
                                   jnp.asarray(prompts[:, t]), jnp.asarray(t))
        out = []
        key = jax.random.key(seed)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, cache = decode(self.params, cache, tok,
                                   jnp.asarray(plen + i))
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / temperature, axis=-1).astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)
