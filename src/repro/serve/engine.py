"""Serving: sharded prefill/decode step builders + a batched engine.

``build_prefill_step`` / ``build_decode_step`` produce the exact
computations the inference dry-run shapes lower (`prefill_32k` lowers
the full-sequence forward; `decode_32k` / `long_500k` lower ONE decode
step against a materialized KV cache, per the assignment).

Cache sharding: batch on the data axes, heads/state channels on
``model``; for single-sequence long-context (`long_500k`, batch=1) the
policy's ``kv_seq_axis`` shards the cache *length* instead, which GSPMD
turns into flash-decode-style distributed attention.

:class:`ServeEngine` is the production batched loop on top of the
builders: one-shot prefill (a single lowered full-sequence forward per
admitted request, not ``prompt_len`` decode dispatches), continuous
batching over ``max_batch`` slots with per-slot positions (sequences of
mixed prompt lengths admit and retire mid-flight), and a unified
greedy/temperature/top-k sampler applied identically from the *first*
generated token.  Sampling keys are derived per (request, token index),
never from the step loop, so generations are bit-independent of how
requests happen to be batched together.  An optional telemetry sink
(:mod:`repro.serve.telemetry`) accounts the engine's per-step DRAM
traffic into a :class:`repro.core.workload.WorkloadProfile` for the RTC
policy engine.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.axisenv import axis_env
from repro.dist.sharding import ShardingPolicy, param_specs
from repro.models.config import ModelConfig
from repro.models.transformer import TransformerLM

__all__ = ["cache_specs", "build_prefill_step", "build_decode_step",
           "Request", "ServeEngine"]


def cache_specs(model: TransformerLM, batch: int, cache_len: int,
                policy: ShardingPolicy, kv_seq_axis=None,
                model_axis_size: Optional[int] = None):
    """PartitionSpec tree matching ``model.init_cache(batch, cache_len)``.

    KV placement mirrors ``attention.attn_decode``: shard heads on the
    model axis when there are enough KV heads to fill it, otherwise
    shard the cache length (flash-decode).  ``kv_seq_axis`` overrides
    (long_500k shards the length over the whole mesh).
    """
    cfg = model.cfg
    b = policy.batch_spec if batch > 1 else None
    m = policy.model_axis
    shapes = jax.eval_shape(lambda: model.init_cache(batch, cache_len))
    heads_fit = (model_axis_size is not None and cfg.n_kv_heads > 0
                 and cfg.n_kv_heads % model_axis_size == 0)

    def one(path, leaf):
        name = str(getattr(path[-1], "name", getattr(path[-1], "key", "")))
        nd = len(leaf.shape)
        # "groups" caches carry a leading stacked-group axis; "tail"
        # caches (pattern remainder layers) do not.
        top = str(getattr(path[0], "key", ""))
        lead = (None,) if top == "groups" else ()
        if name in ("k", "v"):            # [(G,) B, L, KV, hd]
            if kv_seq_axis is not None:
                return P(*lead, b, kv_seq_axis, None, None)
            if heads_fit:
                return P(*lead, b, None, m, None)
            return P(*lead, b, m, None, None)
        if name == "length":
            return P(*([None] * nd))
        if name == "conv":                 # [(G,) B, k-1, width]
            return P(*lead, b, None, m)
        if name == "h":
            if nd == len(lead) + 3:        # ssm: [(G,) B, di, n]
                return P(*lead, b, m, None)
            return P(*lead, b, m)          # rglru: [(G,) B, dl]
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, shapes)


def build_prefill_step(model: TransformerLM, mesh: Mesh,
                       policy: ShardingPolicy, donate: bool = False,
                       last_only: bool = True,
                       cache_len: Optional[int] = None):
    """Full-sequence forward with sharded params/batch.

    ``last_only`` (production default): unembed only the final position
    — serving prefill needs the first sampled token, not [b, s, vocab]
    logits (4.2 GiB/device of pure output for gemma2-9b @32k).

    ``cache_len`` (serving): also materialize the decode cache — the
    jitted function then lowers ``model.prefill`` and returns
    (last-position logits [b, vocab] f32, cache) with the exact
    ``init_cache(b, cache_len)`` structure, ready for
    ``build_decode_step`` to continue at position ``prompt_len``.
    """
    pspecs = param_specs(jax.eval_shape(
        lambda: model.init(jax.random.key(0))), policy)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(mesh, P(policy.batch_spec, policy.seq_axis))

    def prefill(params, tokens):
        with axis_env(policy, mesh=mesh):
            if cache_len is not None:
                return model.prefill(params, tokens, cache_len)
            if last_only:
                hidden, _ = model.hidden(params, tokens=tokens)
                return model._unembed(params, hidden[:, -1:])
            logits, _ = model.apply(params, tokens=tokens)
            return logits

    return jax.jit(prefill, in_shardings=(psh, tok_sh)), psh, tok_sh


def build_decode_step(model: TransformerLM, mesh: Mesh,
                      policy: ShardingPolicy, batch: int, cache_len: int,
                      kv_seq_axis=None, per_slot_pos: bool = False):
    """One-token decode with sharded KV cache. Returns
    (step_fn, param_shardings, cache_shardings).

    ``per_slot_pos``: the position argument is a [batch] vector (each
    slot decodes its own sequence offset — continuous batching) instead
    of one scalar shared by the whole batch.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = param_specs(jax.eval_shape(
        lambda: model.init(jax.random.key(0))), policy)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    cspecs = cache_specs(model, batch, cache_len, policy, kv_seq_axis,
                         model_axis_size=sizes.get(policy.model_axis))
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(
        mesh, P(policy.batch_spec if batch > 1 else None))
    if per_slot_pos:
        pos_sh = NamedSharding(
            mesh, P(policy.batch_spec if batch > 1 else None))
    else:
        pos_sh = NamedSharding(mesh, P())

    def decode(params, cache, token, pos):
        seq_override = kv_seq_axis if kv_seq_axis is not None else policy.seq_axis
        with axis_env(batch_axes=policy.data_axes if batch > 1 else None,
                      model_axis=policy.model_axis,
                      seq_axis=seq_override, mesh=mesh):
            return model.decode_step(params, cache, token, pos)

    step = jax.jit(
        decode,
        in_shardings=(psh, csh, tok_sh, pos_sh),
        out_shardings=(NamedSharding(mesh, P(
            policy.batch_spec if batch > 1 else None, None)), csh),
        donate_argnums=(1,),
    )
    return step, psh, csh


# ---------------------------------------------------------------------------
# Batched serving engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class Request:
    """One admitted generation request (engine-internal ids).

    ``eq=False``: the ndarray prompt makes generated equality/hash
    raise; identity comparison is the useful semantic for requests.
    """
    req_id: int
    prompt: np.ndarray          # [plen] int32, plen >= 1
    max_new_tokens: int


class _Slot:
    """Mutable scheduler state of one occupied batch slot."""
    __slots__ = ("req", "pos", "emitted", "out")

    def __init__(self, req: Request, pos: int, first_token: int):
        self.req = req
        self.pos = pos            # next decode feed position
        self.emitted = 1          # tokens sampled so far (incl. first)
        self.out = [first_token]


class ServeEngine:
    """Continuous-batching serving loop over ``max_batch`` cache slots.

    Requests of mixed prompt lengths are admitted into free slots
    mid-flight (one-shot prefill + cache insertion), decoded together
    with per-slot positions, and retired on EOS / request budget /
    ``max_len`` — the freed slot is immediately refilled from the
    pending queue.  Slot admission order never changes a request's
    tokens: sampling keys are a pure function of (seed, request id,
    token index).

    Compile note: the prefill function retraces per distinct prompt
    length (exact-length lowering keeps recurrent-state hand-off
    trivially correct — right-padding would feed pad tokens into
    ssm/rglru state).  Length-bucketed prefill with masked positions is
    the production fix and is tracked in the ROADMAP.
    """

    def __init__(self, model: TransformerLM, params: dict,
                 max_len: int = 256, max_batch: int = 8,
                 eos_id: Optional[int] = None, bos_id: Optional[int] = None,
                 mesh: Optional[Mesh] = None,
                 policy: Optional[ShardingPolicy] = None):
        self.model = model
        self.params = params
        self.max_len = int(max_len)
        self.max_batch = int(max_batch)
        self.eos_id = eos_id
        self.bos_id = bos_id
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                        ("data", "model"))
        if policy is None:
            policy = ShardingPolicy.for_mesh(mesh)
        self.mesh, self.policy = mesh, policy
        self._prefill = build_prefill_step(
            model, mesh, policy, cache_len=self.max_len)[0]
        self._decode = build_decode_step(
            model, mesh, policy, batch=self.max_batch,
            cache_len=self.max_len, per_slot_pos=True)[0]
        self._insert = jax.jit(self._insert_cache)
        self._keys = jax.jit(jax.vmap(
            lambda base, r, i: jax.random.fold_in(jax.random.fold_in(base, r), i),
            in_axes=(None, 0, 0)))
        self._samplers = {}

    # ------------------------------------------------------------- sampling
    def _sampler(self, top_k: Optional[int]):
        """Jitted unified sampler: greedy / temperature / top-k.

        Every emitted token — including the one sampled from prefill
        logits — goes through this one function, so ``temperature``
        applies from the first token (the seed engine argmaxed it).
        """
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        if top_k in self._samplers:
            return self._samplers[top_k]

        def sample(logits, keys, temperature):
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
            if top_k is not None and top_k < logits.shape[-1]:
                kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
                scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
            drawn = jax.vmap(jax.random.categorical)(keys, scaled)
            return jnp.where(temperature > 0, drawn.astype(jnp.int32), greedy)

        fn = jax.jit(sample)
        self._samplers[top_k] = fn
        return fn

    # ---------------------------------------------------------- cache insert
    @staticmethod
    def _insert_cache(cache, one, slot):
        """Write a prefilled batch-1 cache into batch slot ``slot``."""
        def ins(path, big, small):
            name = str(getattr(path[-1], "name",
                               getattr(path[-1], "key", "")))
            if name == "length":
                # single high-water mark shared by the batch; the decode
                # path recomputes per-slot validity from positions.
                return jnp.maximum(big, small)
            ax = 1 if str(getattr(path[0], "key", "")) == "groups" else 0
            start = [0] * big.ndim
            start[ax] = slot
            return jax.lax.dynamic_update_slice(big, small, tuple(start))

        return jax.tree_util.tree_map_with_path(ins, cache, one)

    # -------------------------------------------------------------- requests
    def _admit_prompt(self, prompt) -> np.ndarray:
        p = np.asarray(prompt, np.int32).reshape(-1)
        if p.size == 0:
            if self.bos_id is None:
                raise ValueError(
                    "empty prompt: generation must start from at least one "
                    "token; construct the engine with bos_id= to serve "
                    "BOS-only requests")
            p = np.asarray([self.bos_id], np.int32)
        if p.size > self.max_len:
            raise ValueError(
                f"prompt length {p.size} exceeds engine max_len {self.max_len}")
        return p

    # ----------------------------------------------------------------- serve
    def serve(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
              temperature: float = 0.0, top_k: Optional[int] = None,
              seed: int = 0, eos_id: Optional[int] = None,
              telemetry=None) -> List[np.ndarray]:
        """Serve a batch of requests with continuous batching.

        prompts: sequence of 1-D int32 token arrays (mixed lengths fine;
        empty prompts require ``bos_id``).  Returns the generated tokens
        of each request, in input order (each up to ``max_new_tokens``,
        shorter on EOS or cache exhaustion).  ``eos_id`` overrides the
        engine default for this call.  ``telemetry`` is an optional sink
        with ``record_prefill(plen, dt)`` / ``record_decode(ctx_lengths,
        dt)`` hooks — see :class:`repro.serve.telemetry.ServeTelemetry`.
        """
        if max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        eos = self.eos_id if eos_id is None else eos_id
        requests = [Request(i, self._admit_prompt(p), max_new_tokens)
                    for i, p in enumerate(prompts)]
        outputs: List[Optional[np.ndarray]] = [None] * len(requests)
        if max_new_tokens == 0:
            return [np.zeros((0,), np.int32) for _ in requests]

        B = self.max_batch
        sample = self._sampler(top_k)
        base = jax.random.key(seed)
        temp = float(temperature)
        cache = self.model.init_cache(B, self.max_len)
        slots: List[Optional[_Slot]] = [None] * B
        tok_vec = np.zeros((B,), np.int32)
        pos_vec = np.zeros((B,), np.int32)
        req_vec = np.zeros((B,), np.int32)
        emit_vec = np.zeros((B,), np.int32)
        pending = collections.deque(requests)

        def retire(s: int):
            st = slots[s]
            outputs[st.req.req_id] = np.asarray(st.out, np.int32)
            slots[s] = None

        def finished(st: _Slot, token: int) -> bool:
            if st.emitted >= st.req.max_new_tokens:
                return True
            if eos is not None and token == eos:
                return True
            return st.pos >= self.max_len    # cache exhausted

        def admit():
            nonlocal cache
            for s in range(B):
                while slots[s] is None and pending:
                    req = pending.popleft()
                    plen = req.prompt.shape[0]
                    t0 = time.perf_counter()
                    logits, one = self._prefill(self.params,
                                                jnp.asarray(req.prompt[None]))
                    cache = self._insert(cache, one, jnp.asarray(s, jnp.int32))
                    key = self._keys(base, np.asarray([req.req_id], np.int32),
                                     np.zeros((1,), np.int32))
                    first = int(np.asarray(
                        sample(logits, key, jnp.float32(temp)))[0])
                    if telemetry is not None:
                        telemetry.record_prefill(
                            plen, time.perf_counter() - t0)
                    st = _Slot(req, pos=plen, first_token=first)
                    slots[s] = st
                    tok_vec[s], pos_vec[s] = first, plen
                    req_vec[s], emit_vec[s] = req.req_id, st.emitted
                    if finished(st, first):
                        retire(s)           # keep admitting into this slot

        admit()
        while any(st is not None for st in slots):
            active = [s for s in range(B) if slots[s] is not None]
            ctx = [int(pos_vec[s]) + 1 for s in active]
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tok_vec),
                                         jnp.asarray(pos_vec))
            keys = self._keys(base, req_vec, emit_vec)
            toks = np.asarray(sample(logits, keys, jnp.float32(temp)))
            if telemetry is not None:
                telemetry.record_decode(ctx, time.perf_counter() - t0)
            for s in active:
                st = slots[s]
                token = int(toks[s])
                st.out.append(token)
                st.emitted += 1
                st.pos += 1
                tok_vec[s], pos_vec[s], emit_vec[s] = token, st.pos, st.emitted
                if finished(st, token):
                    retire(s)
            admit()
        return outputs  # type: ignore[return-value]

    # -------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, n_new: int,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0, eos_id: Optional[int] = None) -> np.ndarray:
        """prompts: [b, prompt_len] int32 -> [b, n_new] int32.

        Batch-API wrapper over :meth:`serve`; sequences that retire
        early are right-padded with the EOS id, or with -1 (never a
        valid vocab id) when no EOS is configured — cache-exhaustion
        truncation must stay distinguishable from generated tokens.
        """
        prompts = np.asarray(prompts, np.int32)
        outs = self.serve(list(prompts), n_new, temperature=temperature,
                          top_k=top_k, seed=seed, eos_id=eos_id)
        eos = self.eos_id if eos_id is None else eos_id
        pad = eos if eos is not None else -1
        full = np.full((len(outs), n_new), pad, np.int32)
        for i, o in enumerate(outs):
            full[i, :o.shape[0]] = o
        return full
