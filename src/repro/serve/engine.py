"""Serving: sharded prefill/decode step builders + a batched engine.

``build_prefill_step`` / ``build_decode_step`` produce the exact
computations the inference dry-run shapes lower (`prefill_32k` lowers
the full-sequence forward; `decode_32k` / `long_500k` lower ONE decode
step against a materialized KV cache, per the assignment).

Cache sharding: batch on the data axes, heads/state channels on
``model``; for single-sequence long-context (`long_500k`, batch=1) the
policy's ``kv_seq_axis`` shards the cache *length* instead, which GSPMD
turns into flash-decode-style distributed attention.

:class:`ServeEngine` is the production batched loop on top of the
builders: one-shot prefill (a single lowered full-sequence forward per
admitted request, not ``prompt_len`` decode dispatches), continuous
batching over ``max_batch`` slots with per-slot positions (sequences of
mixed prompt lengths admit and retire mid-flight), and a unified
greedy/temperature/top-k sampler applied identically from the *first*
generated token.  Sampling keys are derived per (request, token index),
never from the step loop, so generations are bit-independent of how
requests happen to be batched together.  An optional telemetry sink
(:mod:`repro.serve.telemetry`) accounts the engine's per-step DRAM
traffic into a :class:`repro.core.workload.WorkloadProfile` for the RTC
policy engine.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.axisenv import axis_env
from repro.dist.sharding import ShardingPolicy, param_specs
from repro.models.attention import RESERVED_PAGES, PagedKVCache
from repro.models.config import ModelConfig
from repro.models.rglru import PagedRGLRUCache
from repro.models.ssm import PagedSSMCache
from repro.models.transformer import TransformerLM
from repro.serve.paging import (PagedCacheConfig, PageTable, PrefixKeys,
                                prefix_page_keys, slot_floor)

__all__ = ["cache_specs", "build_prefill_step", "build_decode_step",
           "PrefillBuckets", "Request", "ServeEngine"]


def cache_specs(model: TransformerLM, batch: int, cache_len: int,
                policy: ShardingPolicy, kv_seq_axis=None,
                model_axis_size: Optional[int] = None,
                cache_factory=None):
    """PartitionSpec tree matching ``model.init_cache(batch, cache_len)``
    (or ``cache_factory()`` — e.g. a paged cache structure).

    KV placement mirrors ``attention.attn_decode``: shard heads on the
    model axis when there are enough KV heads to fill it, otherwise
    shard the cache length (flash-decode).  ``kv_seq_axis`` overrides
    (long_500k shards the length over the whole mesh).

    Paged-cache leaves (``kp``/``vp`` pools, ``conv_p``/``h_p`` state
    pools, ``block`` tables): pools have no batch dim, so the *page*
    dim takes the data axes instead (``ShardingPolicy.page_spec`` —
    only when provably divisible), heads/state channels keep the model
    axis, and block tables shard their *slot* dim over the data axes
    (``ShardingPolicy.slot_spec``): under the device-local page layout
    each device holds exactly the table rows of the slots pinned to its
    pool extent, which is what lets the ``shard_map`` decode step read
    pools with no collective at all (indivisible slot counts
    replicate, which always lowers).
    """
    cfg = model.cfg
    b = policy.batch_spec if batch > 1 else None
    m = policy.model_axis
    shapes = jax.eval_shape(cache_factory if cache_factory is not None
                            else lambda: model.init_cache(batch, cache_len))
    heads_fit = (model_axis_size is not None and cfg.n_kv_heads > 0
                 and cfg.n_kv_heads % model_axis_size == 0)

    def one(path, leaf):
        name = str(getattr(path[-1], "name", getattr(path[-1], "key", "")))
        nd = len(leaf.shape)
        # "groups" caches carry a leading stacked-group axis; "tail"
        # caches (pattern remainder layers) do not.
        top = str(getattr(path[0], "key", ""))
        lead = (None,) if top == "groups" else ()
        if name in ("k", "v"):            # [(G,) B, L, KV, hd]
            if kv_seq_axis is not None:
                return P(*lead, b, kv_seq_axis, None, None)
            if heads_fit:
                return P(*lead, b, None, m, None)
            return P(*lead, b, m, None, None)
        if name in ("kp", "vp"):          # [(G,) n_pages, P, KV, hd]
            n_pages = leaf.shape[len(lead)]
            if kv_seq_axis is not None:
                # same no-padding rule as page_spec: pjit argument
                # shardings reject indivisible dims, so only shard the
                # page dim when the seq-axis extent provably divides it
                axes = kv_seq_axis if isinstance(kv_seq_axis, tuple) \
                    else (kv_seq_axis,)
                size = 1
                for a in axes:
                    size *= policy.axis_size(a) or 0
                sd = kv_seq_axis if size and n_pages % size == 0 else None
                return P(*lead, sd, None, None, None)
            pd = policy.page_spec(n_pages)
            if heads_fit:
                return P(*lead, pd, None, m, None)
            return P(*lead, pd, None, None, None)
        if name == "block":
            # [(G,) B(, n_lp)] — slot dim rides the data axes with the
            # pool extents; no sharding along kv_seq_axis (the seq-split
            # layout keeps tables replicated for the length gather).
            sd = None if kv_seq_axis is not None \
                else policy.slot_spec(leaf.shape[len(lead)])
            rest = [None] * (nd - len(lead) - 1)
            return P(*lead, sd, *rest)
        if name == "length":
            return P(*([None] * nd))
        if name in ("conv", "conv_p"):     # [(G,) B|n_sp, k-1, width]
            cb = b if name == "conv" \
                else policy.page_spec(leaf.shape[len(lead)])
            return P(*lead, cb, None, m)
        if name in ("h", "h_p"):
            # state pools take the page placement KV pools get: the
            # page dim is the capacity dim, and leaving it replicated
            # makes the per-device state bill grow with the mesh (the
            # partition pass's invariance gate caught exactly this)
            hb = b if name == "h" \
                else policy.page_spec(leaf.shape[len(lead)])
            if nd == len(lead) + 3:        # ssm: [(G,) B|n_sp, di, n]
                return P(*lead, hb, m, None)
            return P(*lead, hb, m)         # rglru: [(G,) B|n_sp, dl]
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, shapes)


def build_prefill_step(model: TransformerLM, mesh: Mesh,
                       policy: ShardingPolicy, donate: bool = False,
                       last_only: bool = True,
                       cache_len: Optional[int] = None,
                       batch: Optional[int] = None):
    """Full-sequence forward with sharded params/batch.

    ``last_only`` (production default): unembed only the final position
    — serving prefill needs the first sampled token, not [b, s, vocab]
    logits (4.2 GiB/device of pure output for gemma2-9b @32k).

    ``cache_len`` (serving): also materialize the decode cache — the
    jitted function then lowers ``model.prefill`` and takes a third
    ``lengths`` argument ([b] int32, real prompt lengths of the
    right-padded ``tokens``), returning (logits at ``length-1``
    [b, vocab] f32, cache) with the exact ``init_cache(b, cache_len)``
    structure, ready for ``build_decode_step`` to continue at position
    ``length``.

    ``batch``: the token batch size this step will be fed (the serving
    engine prefills one request at a time).  A batch of 1 replicates
    the batch dimension instead of sharding it — a size-1 dim cannot be
    laid out over a >1-device data axis.
    """
    pspecs = param_specs(jax.eval_shape(
        lambda: model.init(jax.random.key(0))), policy)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    bspec = policy.batch_spec if (batch is None or batch > 1) else None
    tok_sh = NamedSharding(mesh, P(bspec, policy.seq_axis))

    if cache_len is not None:
        def prefill_cached(params, tokens, lengths):
            with axis_env(policy, mesh=mesh):
                return model.prefill(params, tokens, cache_len,
                                     lengths=lengths)

        len_sh = NamedSharding(mesh, P(bspec))
        return jax.jit(prefill_cached,
                       in_shardings=(psh, tok_sh, len_sh)), psh, tok_sh

    def prefill(params, tokens):
        with axis_env(policy, mesh=mesh):
            if last_only:
                hidden, _ = model.hidden(params, tokens=tokens)
                return model._unembed(params, hidden[:, -1:])
            logits, _ = model.apply(params, tokens=tokens)
            return logits

    return jax.jit(prefill, in_shardings=(psh, tok_sh)), psh, tok_sh


def _is_paged_node(x) -> bool:
    return isinstance(x, (PagedKVCache, PagedSSMCache, PagedRGLRUCache))


def _shift_block_ids(cache, shift):
    """Add ``shift * local_pool_extent`` to every paged node's block
    table (``shift`` may be a traced scalar).  Inside a ``shard_map``
    body the pool leaves are already device-local, so each node's own
    page-dim extent *is* the per-shard extent — ``-shard_index``
    rebases global page ids to local pool offsets, ``+shard_index``
    restores them."""
    def one(node):
        if isinstance(node, PagedKVCache):
            ext = node.kp.shape[node.kp.ndim - 4]   # [(G,) n_pages, P, kvh, hd]
            return dataclasses.replace(node, block=node.block + shift * ext)
        ext = node.conv_p.shape[node.conv_p.ndim - 3]  # [(G,) n_sp, k-1, d]
        return dataclasses.replace(node, block=node.block + shift * ext)

    return jax.tree.map(one, cache, is_leaf=_is_paged_node)


def build_decode_step(model: TransformerLM, mesh: Mesh,
                      policy: ShardingPolicy, batch: int, cache_len: int,
                      kv_seq_axis=None, per_slot_pos: bool = False,
                      cache_factory=None, decode_backend: str = "gather",
                      donate_cache: bool = True, shards: int = 1):
    """One-token decode with sharded KV cache. Returns
    (step_fn, param_shardings, cache_shardings).

    ``per_slot_pos``: the position argument is a [batch] vector (each
    slot decodes its own sequence offset — continuous batching) instead
    of one scalar shared by the whole batch.

    ``cache_factory``: overrides the cache structure the step is lowered
    for (the paged engine passes ``PageTable.init_cache`` so the step
    consumes pool + block-table leaves instead of contiguous buffers).

    ``decode_backend``: paged-cache attention path — ``"gather"``
    materializes the logical view, ``"pallas_paged"`` runs the
    block-table Pallas kernel in place.  The cache shardings are the
    same either way (pool page dims keep ``ShardingPolicy.page_spec``):
    the kernel is opaque to GSPMD, which gathers its operands around
    the call while the cache itself stays sharded across steps.

    ``donate_cache``: donate the cache argument into the step (the
    default; in/out cache shardings match, so XLA updates the buffers —
    including paged pool pages — in place instead of copying the full
    cache every token).  The static analyzer's donation lint
    (``repro.analysis``) checks the lowered executable actually carries
    the donation, and its per-step byte accounting *assumes* it: an
    un-donated cache is a copy the traffic cross-check would miss.
    Disable only to lower a step whose caller must keep the input cache
    alive (e.g. checkpoint-restore debugging).

    ``shards``: number of device-local pool extents the paged cache
    geometry was built with (:class:`repro.serve.paging.PageTable`).
    When it matches the mesh's data extent (and every non-data axis has
    size 1, no ``kv_seq_axis``), the step is built as a **shard_map**
    computation: each device rebases its (global-id) block-table rows
    into its local pool extent, runs the full decode — including the
    opaque Pallas paged-attention kernel — strictly device-locally, and
    restores global ids on the way out; the replicated cache ``length``
    is recomputed globally outside the mapped region with the exact
    per-backend formula (``min(max(pos)+1, cache_len)``), so
    generations are bit-identical to the solo/GSPMD step.  No
    collective with a pool operand is lowered at any mesh size — the
    property ``repro.analysis`` gates.  On any mismatch the builder
    falls back to the plain GSPMD step, which is always correct (the
    global-id layout decodes unmapped as-is) but gathers the pools
    around the kernel.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = param_specs(jax.eval_shape(
        lambda: model.init(jax.random.key(0))), policy)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    cspecs = cache_specs(model, batch, cache_len, policy, kv_seq_axis,
                         model_axis_size=sizes.get(policy.model_axis),
                         cache_factory=cache_factory)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(
        mesh, P(policy.batch_spec if batch > 1 else None))
    if per_slot_pos:
        pos_sh = NamedSharding(
            mesh, P(policy.batch_spec if batch > 1 else None))
    else:
        pos_sh = NamedSharding(mesh, P())

    def decode(params, cache, token, pos):
        seq_override = kv_seq_axis if kv_seq_axis is not None else policy.seq_axis
        with axis_env(batch_axes=policy.data_axes if batch > 1 else None,
                      model_axis=policy.model_axis,
                      seq_axis=seq_override, mesh=mesh):
            return model.decode_step(params, cache, token, pos,
                                     decode_backend=decode_backend)

    data_size = 1
    for a in policy.data_axes:
        data_size *= sizes.get(a, 1)
    use_shard_map = (
        cache_factory is not None and shards > 1 and kv_seq_axis is None
        and data_size == shards
        # FSDP/ZeRO scatter params over the data axes; under a manual
        # map nothing re-gathers them, so the body would compute on
        # weight shards — GSPMD fallback stays correct there.
        and not policy.fsdp and not policy.zero1
        and all(s == 1 for a, s in sizes.items()
                if a not in policy.data_axes))
    if use_shard_map:
        from jax.experimental.shard_map import shard_map

        bspec = policy.batch_spec
        logit_spec = P(bspec, None)

        def body(params, cache, token, pos):
            # flat data-shard index, from static axis sizes (partition-id
            # arithmetic only — no collective may appear in this body)
            g = jnp.int32(0)
            for a in policy.data_axes:
                g = g * sizes.get(a, 1) + jax.lax.axis_index(a)
            local = _shift_block_ids(cache, -g)
            # mesh=None env: `constrain` is the identity — the body is
            # already device-local, GSPMD has nothing to place.
            with axis_env(batch_axes=None, model_axis=None, seq_axis=None,
                          mesh=None):
                logits, new_cache = model.decode_step(
                    params, local, token, pos,
                    decode_backend=decode_backend)
            new_cache = _shift_block_ids(new_cache, g)
            # `length` is replicated (out_spec P()): pass the incoming
            # replicated value through; the wrapper below recomputes it
            # from the *global* position vector, exactly as the unmapped
            # step does — per-device lengths would diverge.
            new_cache = jax.tree.map(
                lambda new, old: (dataclasses.replace(new, length=old.length)
                                  if isinstance(new, PagedKVCache) else new),
                new_cache, cache, is_leaf=_is_paged_node)
            return logits, new_cache

        smap = shard_map(
            body, mesh=mesh,
            in_specs=(pspecs, cspecs, P(bspec),
                      P(bspec) if per_slot_pos else P()),
            out_specs=(logit_spec, cspecs),
            check_rep=False)

        def decode_sm(params, cache, token, pos):
            logits, new_cache = smap(params, cache, token, pos)
            new_cache = jax.tree.map(
                lambda new: (dataclasses.replace(
                    new, length=jnp.broadcast_to(
                        jnp.minimum(jnp.max(pos) + 1,
                                    new.cache_len).astype(jnp.int32),
                        new.length.shape))
                    if isinstance(new, PagedKVCache) else new),
                new_cache, is_leaf=_is_paged_node)
            return logits, new_cache

        fn = decode_sm
    else:
        fn = decode

    step = jax.jit(
        fn,
        in_shardings=(psh, csh, tok_sh, pos_sh),
        out_shardings=(NamedSharding(mesh, P(
            policy.batch_spec if batch > 1 else None, None)), csh),
        donate_argnums=(1,) if donate_cache else (),
    )
    return step, psh, csh


# ---------------------------------------------------------------------------
# Prefill bucketing policy
# ---------------------------------------------------------------------------
class PrefillBuckets:
    """Length-bucket ladder for prefill, with pad-waste accounting.

    Prompts are right-padded up to the smallest ladder entry that fits
    (best-fit), so the number of distinct prefill shapes — and therefore
    the number of lowered prefill executables — is bounded by
    ``len(ladder)`` regardless of the traffic's length distribution.
    Entries above ``max_len`` are dropped and ``max_len`` itself is
    always the top rung (every admissible prompt fits somewhere).

    Counters accumulate across serve calls: ``hits`` per bucket,
    ``real_tokens`` vs ``padded_tokens``, and ``pad_waste`` (the
    fraction of padded prefill positions that carried no prompt token)
    — the knob to watch when tuning a ladder against a traffic mix.
    """

    def __init__(self, ladder: Sequence[int], max_len: Optional[int] = None):
        rungs = sorted({int(x) for x in ladder})
        if not rungs or rungs[0] < 1:
            raise ValueError(f"bucket ladder must be positive ints: {ladder}")
        if max_len is not None:
            rungs = [x for x in rungs if x < max_len] + [int(max_len)]
        self.ladder: Tuple[int, ...] = tuple(rungs)
        self.hits = {x: 0 for x in self.ladder}
        self.real_tokens = 0
        self.padded_tokens = 0

    @classmethod
    def powers_of_two(cls, max_len: int, min_bucket: int = 8
                      ) -> "PrefillBuckets":
        """Default ladder: min_bucket, 2*min_bucket, ... capped at max_len."""
        if min_bucket < 1:
            raise ValueError(f"min_bucket must be >= 1, got {min_bucket}")
        rungs, b = [], int(min_bucket)
        while b < max_len:
            rungs.append(b)
            b *= 2
        return cls(rungs + [int(max_len)], max_len=max_len)

    def bucket_for(self, plen: int) -> int:
        """Smallest rung that fits ``plen`` (best-fit)."""
        for b in self.ladder:
            if plen <= b:
                return b
        raise ValueError(
            f"prompt length {plen} exceeds top bucket {self.ladder[-1]}")

    def record(self, plen: int, bucket: int) -> None:
        self.hits[bucket] += 1
        self.real_tokens += int(plen)
        self.padded_tokens += int(bucket)

    @property
    def pad_waste(self) -> float:
        """Fraction of prefilled positions that were padding."""
        if not self.padded_tokens:
            return 0.0
        return 1.0 - self.real_tokens / self.padded_tokens

    def stats(self) -> dict:
        return {"ladder": self.ladder,
                "hits": dict(self.hits),
                "real_tokens": self.real_tokens,
                "padded_tokens": self.padded_tokens,
                "pad_waste": self.pad_waste}

    def summary(self) -> str:
        hits = " ".join(f"{b}:{n}" for b, n in self.hits.items() if n)
        return (f"buckets {list(self.ladder)} hits [{hits}] "
                f"pad waste {self.pad_waste:.1%} "
                f"({self.padded_tokens - self.real_tokens} of "
                f"{self.padded_tokens} prefill positions)")


# ---------------------------------------------------------------------------
# Batched serving engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class Request:
    """One admitted generation request (engine-internal ids).

    ``eq=False``: the ndarray prompt makes generated equality/hash
    raise; identity comparison is the useful semantic for requests.
    Sampling params live on the request — mixed greedy/temperature
    traffic batches together, each request keeping its own schedule-
    independent generation.
    """
    req_id: int
    prompt: np.ndarray          # [plen] int32, plen >= 1
    max_new_tokens: int
    temperature: float = 0.0
    top_k: Optional[int] = None
    # arrival index: the scheduler's FCFS/victim ordering (req_id is the
    # caller-visible sampling identity and may arrive in any order)
    order: int = 0
    # content-addressed page keys when the engine serves with prefix
    # sharing (None otherwise)
    keys: Optional[PrefixKeys] = None


class _Slot:
    """Mutable scheduler state of one occupied batch slot.

    ``feed`` (suffix-feed sharing only): remaining prompt tokens to
    teacher-force through the decode step before sampling starts; None
    on every other path."""
    __slots__ = ("req", "pos", "emitted", "out", "feed")

    def __init__(self, req: Request, pos: int, first_token: int):
        self.req = req
        self.pos = pos            # next decode feed position
        self.emitted = 1          # tokens sampled so far (incl. first)
        self.out = [first_token]
        self.feed = None


class _Suspended:
    """A preempted request: host-offloaded pages + scheduler state.

    Created when the paged engine must reclaim a victim's device pages
    mid-generation; resumed (bit-identically — sampling keys are
    (request, token-index)-addressed) once a batch slot and enough free
    pages exist.
    """
    __slots__ = ("req", "pos", "emitted", "out", "next_tok", "payload",
                 "feed")

    def __init__(self, req, pos, emitted, out, next_tok, payload,
                 feed=None):
        self.req = req
        self.pos = pos
        self.emitted = emitted
        self.out = out
        self.next_tok = next_tok
        self.payload = payload
        self.feed = feed


class ServeEngine:
    """Continuous-batching serving loop over ``max_batch`` cache slots.

    Requests of mixed prompt lengths are admitted into free slots
    mid-flight (one-shot prefill + cache insertion), decoded together
    with per-slot positions, and retired on EOS / request budget /
    ``max_len`` — the freed slot is immediately refilled from the
    pending queue.  Slot admission order never changes a request's
    tokens: sampling keys are a pure function of (seed, request id,
    token index).

    Compile note: prompts are right-padded up to a
    :class:`PrefillBuckets` ladder and prefilled through the masked
    ``model.prefill(..., lengths=...)`` path, so the number of lowered
    prefill executables is bounded by the ladder size regardless of the
    traffic's length distribution — and padding provably cannot perturb
    a generation (attention masks padded keys, recurrent ssm/rglru
    state carries through padded steps as an exact identity, MoE
    dispatch excludes padded tokens, and the logits/cache hand-off is
    taken at ``length-1``).

    Sampling params (``temperature`` / ``top_k``) are per *request*:
    ``serve`` accepts either one value for the whole call or a
    per-prompt sequence, and a mixed greedy+stochastic batch reproduces
    each request's solo generation bit-for-bit.

    ``paged=PagedCacheConfig(...)`` switches the decode cache to
    block-table paging (:mod:`repro.serve.paging` — design note in the
    package docstring): slots grow page lists allocate-on-write up to
    ``max_ctx`` (which may exceed ``max_len``, the prefill cap), and
    when the resident-page budget runs dry the newest live request is
    preempted, its pages offloaded to host, and resumed — bit-
    identically — once pages free up.  Paged and contiguous serving
    produce identical tokens for any in-budget workload.

    ``decode_backend`` selects how paged attention resolves the block
    tables: ``"gather"`` (default) materializes the contiguous logical
    view every step — bit-identical to contiguous serving but a full
    cache-length copy per layer per step; ``"pallas_paged"`` runs the
    :mod:`repro.kernels.paged_attention` kernel, which reads K/V pages
    through the block-table indirection in place (interpret mode on
    CPU).  Generations are identical across backends on every arch
    (logits agree to accumulation-order tolerance; pinned in
    ``tests/test_paged_attention_kernel.py``), and telemetry accounts
    only true per-page reads on the kernel path — no materialized-view
    traffic.

    ``PagedCacheConfig(sharing=PrefixSharingConfig(...))`` turns on
    prefix sharing (PR 10 — full design note in the
    :mod:`repro.serve` package docstring): prompts are chain-hashed
    into per-page content keys at submission, admission attaches
    registry hits instead of re-allocating (copy-on-write protects the
    shared pages — :class:`~repro.serve.paging.PageTable`), an
    exact-duplicate prompt skips its prefill outright by replaying the
    memoized first-token logits and restoring recurrent state from a
    host snapshot, and the admission scheduler groups same-prefix
    pending requests so their residency windows overlap.  Both default
    paths are bit-identical to unshared serving on every arch (the
    all-arch suite in ``tests/test_prefix_sharing.py`` pins it); the
    opt-in ``suffix_feed`` path trades that guarantee for skipped
    prefill compute on attention-only models.
    """

    def __init__(self, model: TransformerLM, params: dict,
                 max_len: int = 256, max_batch: int = 8,
                 eos_id: Optional[int] = None, bos_id: Optional[int] = None,
                 mesh: Optional[Mesh] = None,
                 policy: Optional[ShardingPolicy] = None,
                 buckets=None, paged=None, decode_backend: str = "gather"):
        self.model = model
        self.params = params
        self.max_len = int(max_len)
        self.max_batch = int(max_batch)
        self.eos_id = eos_id
        self.bos_id = bos_id
        if decode_backend not in ("gather", "pallas_paged"):
            raise ValueError(
                f"decode_backend must be 'gather' or 'pallas_paged', "
                f"got {decode_backend!r}")
        self.decode_backend = decode_backend
        if paged is True:
            paged = PagedCacheConfig()
        self.paged: Optional[PagedCacheConfig] = paged or None
        if decode_backend == "pallas_paged" and self.paged is None:
            raise ValueError(
                "decode_backend='pallas_paged' consumes block tables: "
                "construct the engine with paged=PagedCacheConfig(...)")
        if self.paged is not None:
            self.max_ctx = int(self.paged.max_ctx or self.max_len)
            if self.max_ctx < self.max_len:
                raise ValueError(
                    f"PagedCacheConfig.max_ctx={self.max_ctx} < engine "
                    f"max_len {self.max_len}: the prefill cap cannot "
                    f"exceed the logical context capacity")
            # fail on a bad paged config NOW, before the (expensive)
            # prefill/decode builders lower anything — the same checks
            # PageTable applies, surfaced with the config field named.
            self.paged.validate(model.cfg, self.max_ctx)
        else:
            self.max_ctx = self.max_len
        if buckets is None:
            buckets = PrefillBuckets.powers_of_two(self.max_len)
        elif not isinstance(buckets, PrefillBuckets):
            buckets = PrefillBuckets(buckets, max_len=self.max_len)
        if buckets.ladder[-1] != self.max_len:
            # a short ladder leaves admissible prompts (plen <= max_len)
            # with no bucket and fails mid-serve after other requests
            # already ran; a tall one lowers shapes past the cache that
            # only ever carry masked padding.  The clipped constructor
            # always tops out at exactly max_len.
            raise ValueError(
                f"bucket ladder top {buckets.ladder[-1]} != engine "
                f"max_len {self.max_len}: pass the raw ladder (or build "
                f"with PrefillBuckets(ladder, max_len=...)) so it is "
                f"clipped and capped to the engine")
        self.buckets = buckets
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                        ("data", "model"))
        if policy is None:
            policy = ShardingPolicy.for_mesh(mesh)
        self.mesh, self.policy = mesh, policy
        # prefill materializes a max_ctx-long contiguous cache (== max_len
        # unless paged): positions are then identical between the
        # prefilled cache and the (possibly longer) decode layout, so
        # slot insertion is a pure copy/scatter for every layer kind.
        self._prefill = build_prefill_step(
            model, mesh, policy, cache_len=self.max_ctx, batch=1)[0]
        sh = self.paged.sharing if self.paged is not None else None
        self._sharing = sh if (sh is not None and sh.enabled) else None
        if self.paged is not None:
            shards = self._resolve_shards()
            self._table = PageTable(
                model, self.max_batch, self.max_ctx, self.paged.page_size,
                self.paged.resident_pages,
                state_pages=self.paged.state_pages, shards=shards)
            self._decode, _, self._cache_sh = build_decode_step(
                model, mesh, policy, batch=self.max_batch,
                cache_len=self.max_ctx, per_slot_pos=True,
                cache_factory=self._table.init_cache,
                decode_backend=self.decode_backend, shards=shards)
            self._table.bind_shardings(self._cache_sh)
            self._insert = None
        else:
            self._table = None
            self._decode, _, self._cache_sh = build_decode_step(
                model, mesh, policy, batch=self.max_batch,
                cache_len=self.max_len, per_slot_pos=True)
            # pin the insert output to the decode step's cache shardings,
            # so the slot-update round trip stays layout-stable on real
            # meshes (decode donates and re-emits the same placement).
            # The batch cache is donated: an admit is a single-slot
            # dynamic_update_slice, and without donation every admission
            # copied the full max_batch cache (the donation lint in
            # repro.analysis flagged exactly this executable).
            self._insert = jax.jit(self._insert_cache,
                                   out_shardings=self._cache_sh,
                                   donate_argnums=(0,))
        self._keys = jax.jit(jax.vmap(
            lambda base, r, i: jax.random.fold_in(jax.random.fold_in(base, r), i),
            in_axes=(None, 0, 0)))
        self._sample = jax.jit(self._sample_fn, static_argnums=(4,))

    def _resolve_shards(self) -> int:
        """Device-local pool extents for the paged cache geometry.

        An explicit ``PagedCacheConfig.shards`` wins (the partitioning
        auditor builds mesh-shaped geometry on a compile-only solo
        mesh); otherwise auto-resolve to the mesh's data extent when
        slots and pool budgets split evenly *and* every per-shard
        extent still holds one fully decoded slot — else stay at 1
        (single-pool geometry + GSPMD decode, correct everywhere)."""
        cfgp = self.paged
        if cfgp.shards > 1:
            return cfgp.shards
        shards = self.policy.decode_shards(
            self.max_batch, cfgp.resident_pages, cfgp.state_pages)
        if shards > 1 and cfgp.resident_pages is not None:
            floor = slot_floor(self.model.cfg, self.max_ctx, cfgp.page_size)
            if cfgp.resident_pages // shards < floor:
                return 1
        if shards > 1 and cfgp.state_pages is not None:
            if cfgp.state_pages < self.max_batch + shards * RESERVED_PAGES:
                return 1
        return shards

    @property
    def page_table(self) -> Optional[PageTable]:
        """The engine's :class:`~repro.serve.paging.PageTable` in paged
        mode (``None`` for the contiguous cache) — the public handle to
        the resolved page budget and per-stream allocator state."""
        return self._table

    # ------------------------------------------------------- introspection
    def lowered_artifacts(self, mesh=None,
                          policy: Optional[ShardingPolicy] = None
                          ) -> List[dict]:
        """The engine's lowered executables, packaged for static analysis.

        Returns one entry per executable the serve loop dispatches —
        the decode step, the top prefill bucket, and (contiguous
        engines) the slot-insert — each a dict of the jitted function,
        abstract arguments to trace/lower it with, per-argument roles
        (``params`` / ``cache`` / ``other``), the argnums the engine
        *semantically requires* to be donated, and the argument
        shardings.  Everything is abstract (``jax.eval_shape`` /
        ``ShapeDtypeStruct``): ``repro.analysis`` traces and lowers
        these without executing anything, so an engine constructed with
        abstract params works.  The serve loop itself never calls this.

        ``mesh`` (optionally with ``policy``) rebuilds the step
        functions bound to a *target* mesh — concrete or a
        ``jax.sharding.AbstractMesh`` description — with the engine's
        geometry (batch, context, page budget) unchanged and the
        engine's own executables untouched.  An abstract mesh is bound
        to compile-only host devices via
        :func:`repro.dist.sharding.as_concrete_mesh` (this jax cannot
        lower on an abstract mesh directly); the partitioning pass in
        ``repro.analysis.partition`` uses this to dry-run GSPMD at
        8/64/512 devices on hardware that can execute on at most two.
        """
        if mesh is None and policy is None:
            decode_fn, prefill_fn = self._decode, self._prefill
            insert_fn, cache_sh = self._insert, self._cache_sh
        else:
            from repro.dist.sharding import as_concrete_mesh
            target = mesh if mesh is not None else self.mesh
            lower_mesh = as_concrete_mesh(target)
            pol = policy if policy is not None \
                else ShardingPolicy.for_mesh(target)
            prefill_fn = build_prefill_step(
                self.model, lower_mesh, pol, cache_len=self.max_ctx,
                batch=1)[0]
            if self._table is not None:
                decode_fn, _, cache_sh = build_decode_step(
                    self.model, lower_mesh, pol, batch=self.max_batch,
                    cache_len=self.max_ctx, per_slot_pos=True,
                    cache_factory=self._table.init_cache,
                    decode_backend=self.decode_backend,
                    shards=self._table.shards)
                insert_fn = None
            else:
                decode_fn, _, cache_sh = build_decode_step(
                    self.model, lower_mesh, pol, batch=self.max_batch,
                    cache_len=self.max_len, per_slot_pos=True)
                insert_fn = jax.jit(self._insert_cache,
                                    out_shardings=cache_sh,
                                    donate_argnums=(0,))
        aparams = jax.eval_shape(
            lambda: self.model.init(jax.random.key(0)))
        B = self.max_batch
        if self._table is not None:
            cache = jax.eval_shape(self._table.init_cache)
        else:
            cache = jax.eval_shape(
                lambda: self.model.init_cache(B, self.max_len))
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
        arts = [dict(
            name="decode", fn=decode_fn, args=(aparams, cache, tok, pos),
            roles={0: "params", 1: "cache"},
            expect_donate_argnums=(1,),
            shardings=(None, cache_sh, None, None))]
        top = self.buckets.ladder[-1]
        arts.append(dict(
            name="prefill", fn=prefill_fn,
            args=(aparams, jax.ShapeDtypeStruct((1, top), jnp.int32),
                  jax.ShapeDtypeStruct((1,), jnp.int32)),
            roles={0: "params"}, expect_donate_argnums=(),
            shardings=None))
        if insert_fn is not None:
            one = jax.eval_shape(
                lambda: self.model.init_cache(1, self.max_ctx))
            arts.append(dict(
                name="insert", fn=insert_fn,
                args=(cache, one, jax.ShapeDtypeStruct((), jnp.int32)),
                roles={0: "cache"},
                expect_donate_argnums=(0,),
                shardings=(cache_sh, None, None)))
        return arts

    @property
    def prefill_executables(self) -> int:
        """Distinct lowered prefill executables (one per bucket shape
        traced) — the quantity the ladder bounds.  Read from the jit
        cache when jax exposes it (private introspection, so a getattr
        fallback counts buckets hit instead — equal whenever every
        recorded bucket was lowered by this engine instance)."""
        cache_size = getattr(self._prefill, "_cache_size", None)
        if cache_size is not None:
            return int(cache_size())
        return sum(1 for n in self.buckets.hits.values() if n)

    # ------------------------------------------------------------- sampling
    @staticmethod
    def _sample_fn(logits, keys, temperature, top_k, use_top_k):
        """Unified greedy / temperature / top-k sampler, vectorized over
        per-request params.

        logits [n, vocab]; temperature [n] f32; top_k [n] int32 (the
        vocab size means "no top-k filter": the kth threshold is then
        the row minimum, which keeps every logit bit-unchanged — so a
        no-filter row draws identically whether or not its batch
        company triggered the filter).  ``use_top_k`` is static: calls
        where NO live request filters skip the O(vocab log vocab) row
        sort entirely (the default greedy/temperature hot path).  Every
        emitted token — including the one sampled from prefill logits —
        goes through this one row-wise function, so params apply from
        the first token and a row's draw is independent of its batch
        company.
        """
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits.astype(jnp.float32) \
            / jnp.maximum(temperature, 1e-6)[:, None]
        if use_top_k:
            vocab = logits.shape[-1]
            srt = jnp.sort(scaled, axis=-1)
            kth = jnp.take_along_axis(
                srt, (vocab - jnp.clip(top_k, 1, vocab))[:, None], axis=-1)
            scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
        drawn = jax.vmap(jax.random.categorical)(keys, scaled)
        return jnp.where(temperature > 0, drawn.astype(jnp.int32), greedy)

    @staticmethod
    def _per_request(value, n: int, name: str) -> list:
        """Broadcast a scalar-or-sequence sampling param to one per request.

        ``np.ndim == 0`` (not ``np.isscalar``) so 0-d numpy/jax scalars
        — e.g. a temperature coming out of a jax computation — keep
        working as call-wide values.
        """
        if value is None or np.ndim(value) == 0:
            return [value] * n
        vals = list(value)
        if len(vals) != n:
            raise ValueError(
                f"{name}: got {len(vals)} values for {n} prompts")
        return vals

    # ---------------------------------------------------------- cache insert
    @staticmethod
    def _insert_cache(cache, one, slot):
        """Write a prefilled batch-1 cache into batch slot ``slot``."""
        def ins(path, big, small):
            name = str(getattr(path[-1], "name",
                               getattr(path[-1], "key", "")))
            if name == "length":
                # single high-water mark shared by the batch; the decode
                # path recomputes per-slot validity from positions.
                return jnp.maximum(big, small)
            ax = 1 if str(getattr(path[0], "key", "")) == "groups" else 0
            start = [0] * big.ndim
            start[ax] = slot
            return jax.lax.dynamic_update_slice(big, small, tuple(start))

        return jax.tree_util.tree_map_with_path(ins, cache, one)

    # -------------------------------------------------------------- requests
    def _admit_prompt(self, prompt, idx: int) -> np.ndarray:
        p = np.asarray(prompt, np.int32).reshape(-1)
        if p.size == 0:
            if self.bos_id is None:
                raise ValueError(
                    f"empty prompt at index {idx}: generation must start "
                    "from at least one token; construct the engine with "
                    "bos_id= to serve BOS-only requests")
            p = np.asarray([self.bos_id], np.int32)
        top = self.buckets.ladder[-1]
        if p.size > top:
            # validate here, with the request named, instead of failing
            # opaquely inside PrefillBuckets.bucket_for mid-serve (after
            # other requests already ran).
            raise ValueError(
                f"prompt {idx} has length {p.size}, which exceeds the "
                f"largest prefill bucket {top} (engine max_len "
                f"{self.max_len}); split the prompt or raise max_len")
        return p

    # ----------------------------------------------------------------- serve
    def serve(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
              temperature: float = 0.0, top_k: Optional[int] = None,
              seed: int = 0, eos_id: Optional[int] = None,
              telemetry=None,
              request_ids: Optional[Sequence[int]] = None
              ) -> List[np.ndarray]:
        """Serve a batch of requests with continuous batching.

        prompts: sequence of 1-D int32 token arrays (mixed lengths fine
        — each is padded up to the engine's :class:`PrefillBuckets`
        ladder; empty prompts require ``bos_id``).  Returns the
        generated tokens of each request, in input order (each up to
        ``max_new_tokens``, shorter on EOS or cache exhaustion).

        ``temperature`` / ``top_k`` are per *request*: pass one value
        for the whole call, or a sequence with one entry per prompt
        (greedy and stochastic requests batch together; each request's
        generation matches its solo serve bit-for-bit).  ``eos_id``
        overrides the engine default for this call.  ``telemetry`` is an
        optional sink with ``record_prefill(plen, dt, padded_len)`` /
        ``record_decode(ctx_lengths, dt)`` hooks — see
        :class:`repro.serve.telemetry.ServeTelemetry`; prefill traffic
        is accounted from true prompt lengths, never padded ones.  A
        sink carrying a ``trace``
        (:class:`repro.core.trace.PageAccessTrace`) additionally gets
        the per-step page-access stream of a *paged* engine: each
        decode step records every pool page it read/wrote (KV sweeps +
        appends, state pages), with admissions, restores, and page-out
        reads folded into the step they precede.

        ``request_ids`` — caller-supplied stable id per prompt (default
        ``0..n-1`` in input order).  The id seeds the request's
        sampling keys and labels its telemetry/trace attribution, so it
        MUST be unique within the call: duplicates are rejected up
        front with the colliding indices named (two requests sharing an
        id would silently alias each other's sampling stream).  Outputs
        stay in *input* order regardless of the ids.
        """
        if max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        n_req = len(prompts)
        if request_ids is None:
            rids = list(range(n_req))
        else:
            rids = [int(r) for r in request_ids]
            if len(rids) != n_req:
                raise ValueError(
                    f"request_ids: got {len(rids)} ids for {n_req} prompts")
        seen: Dict[int, int] = {}
        for i, rid in enumerate(rids):
            if rid < 0:
                raise ValueError(
                    f"request id {rid} at index {i} is negative; ids seed "
                    f"sampling keys and must be non-negative ints")
            if rid in seen:
                raise ValueError(
                    f"duplicate request id {rid} at indices {seen[rid]} "
                    f"and {i}: ids address sampling keys and telemetry/"
                    f"trace attribution, so two requests sharing one "
                    f"would silently alias")
            seen[rid] = i
        out_index = seen      # req_id -> position in `prompts`/outputs
        if telemetry is not None:
            # tell the sink which decode path moves the KV bytes (the
            # gather path's materialized logical view is real traffic
            # the kernel path never generates); hasattr-guarded so
            # plain-duck-typed sinks keep working.
            conf = getattr(telemetry, "configure_decode", None)
            if conf is not None:
                conf(backend=self.decode_backend,
                     paged=self._table is not None)
        eos = self.eos_id if eos_id is None else eos_id
        vocab = self.model.cfg.vocab_size
        temps = self._per_request(temperature, len(prompts), "temperature")
        top_ks = self._per_request(top_k, len(prompts), "top_k")
        for i, (t, tk) in enumerate(zip(temps, top_ks)):
            if tk is not None and tk < 1:
                raise ValueError(
                    f"top_k must be >= 1, got {tk} (request {i})")
            # a negative temperature flips the softmax ordering and NaN
            # poisons every draw — reject with the request named, same
            # as the top_k check, instead of sampling garbage silently.
            if t is not None and (not np.isfinite(float(t)) or float(t) < 0):
                raise ValueError(
                    f"temperature must be finite and >= 0, got {t} "
                    f"(request {i})")
        sharing = self._sharing
        requests = []
        for i, (p, t, tk) in enumerate(zip(prompts, temps, top_ks)):
            prompt = self._admit_prompt(p, i)
            keys = (prefix_page_keys(prompt, self.paged.page_size)
                    if sharing is not None else None)
            requests.append(Request(
                rids[i], prompt, max_new_tokens, temperature=float(t),
                top_k=vocab if tk is None else int(tk), order=i, keys=keys))
        outputs: List[Optional[np.ndarray]] = [None] * len(requests)
        if max_new_tokens == 0:
            return [np.zeros((0,), np.int32) for _ in requests]

        B = self.max_batch
        paged = self._table is not None
        use_top_k = any(r.top_k != vocab for r in requests)

        # Page-access trace: page ids are page-table state, so recording
        # rides the host-side scheduling loop — nothing is added to the
        # jitted steps.  Accesses that happen *between* decode steps
        # (admission scatters, restore writes, offload reads) accumulate
        # in pending_pages and fold into the next step's record.
        trace = getattr(telemetry, "trace", None) if telemetry else None
        if trace is not None:
            if not paged:
                raise ValueError(
                    "telemetry.trace set but the engine is not paged — "
                    "page-access traces need a PageTable (pass "
                    "paged=PagedCacheConfig(...) at engine build)")
            names = self._table.stream_names()
            if tuple(trace.stream_names) != names:
                raise ValueError(
                    f"telemetry.trace streams {trace.stream_names} do not "
                    f"match this engine's page table streams {names}")
        pending_pages: Dict[int, set] = {}

        def note_pages(s: int):
            """Fold slot ``s``'s current page set into the next record."""
            if trace is not None:
                for si, pids in self._table.slot_page_ids(s):
                    pending_pages.setdefault(si, set()).update(pids)

        def sample(logits, keys, temps_, topks_):
            return self._sample(logits, keys, temps_, topks_, use_top_k)

        base = jax.random.key(seed)
        if paged:
            self._table.reset()
            cache = self._table.init_cache()
        else:
            cache = self.model.init_cache(B, self.max_len)
        slots: List[Optional[_Slot]] = [None] * B
        tok_vec = np.zeros((B,), np.int32)
        pos_vec = np.zeros((B,), np.int32)
        req_vec = np.zeros((B,), np.int32)
        emit_vec = np.zeros((B,), np.int32)
        temp_vec = np.zeros((B,), np.float32)
        topk_vec = np.full((B,), vocab, np.int32)
        if sharing is not None and sharing.schedule == "prefix":
            # prefix-aware admission: group same-prefix requests so
            # their residency windows overlap (sharing is an in-flight
            # property — a registered page lives only while a slot
            # holds it).  Group order is first arrival, so no group
            # starves; generations are bit-independent of the schedule
            # (sampling keys are (request, token-index)-addressed).
            groups: Dict[bytes, List[Request]] = {}
            for r in requests:
                groups.setdefault(r.keys.group, []).append(r)
            pending = collections.deque(
                r for grp in groups.values() for r in grp)
        else:
            pending = collections.deque(requests)
        suspended: collections.deque = collections.deque()
        # whole-prompt memo: keys.whole -> (first-token logits, state
        # snapshot, plen).  Host-resident, per serve call, FIFO-capped;
        # an exact-duplicate prompt whose pages are all still registered
        # admits through PageTable.admit_cached with no prefill at all.
        memo: Dict[bytes, tuple] = {}

        def occupy(s: int, st: _Slot, next_tok: int):
            slots[s] = st
            tok_vec[s], pos_vec[s] = next_tok, st.pos
            req_vec[s], emit_vec[s] = st.req.req_id, st.emitted
            temp_vec[s], topk_vec[s] = st.req.temperature, st.req.top_k

        def retire(s: int):
            nonlocal cache
            st = slots[s]
            outputs[out_index[st.req.req_id]] = np.asarray(st.out, np.int32)
            slots[s] = None
            if paged:
                cache = self._table.release(cache, s)

        def finished(st: _Slot, token: int) -> bool:
            if st.emitted >= st.req.max_new_tokens:
                return True
            if eos is not None and token == eos:
                return True
            return st.pos >= self.max_ctx    # logical context exhausted

        def suspend(victim: int):
            """Preempt a live slot: offload its pages to host."""
            nonlocal cache
            st = slots[victim]
            note_pages(victim)   # offload reads every held page (before pop)
            cache, payload = self._table.offload(cache, victim, st.pos)
            suspended.append(_Suspended(st.req, st.pos, st.emitted, st.out,
                                        int(tok_vec[victim]), payload,
                                        feed=st.feed))
            slots[victim] = None
            if telemetry is not None:
                telemetry.record_page_out(st.pos)

        def grow():
            """Assign the pages this step's writes need; when a pool
            runs dry, preempt the NEWEST live request — including the
            grower itself, which then suspends and waits FIFO — so the
            oldest admitted request is only ever victimized by its own
            elders (FCFS progress is preserved).  Pages are
            shard-local, so only slots pinned to the grower's shard can
            free the pages it needs — victims come from that shard."""
            nonlocal cache
            cow: List[Tuple[int, int]] = []
            order = sorted((s for s in range(B) if slots[s] is not None),
                           key=lambda s: slots[s].req.order)
            for s in order:
                if slots[s] is None:
                    continue                 # preempted by an earlier grower
                while slots[s] is not None:
                    cache, ok = self._table.prepare_step(
                        cache, s, int(pos_vec[s]), cow_events=cow)
                    if ok:
                        break
                    g = self._table.shard_of(s)
                    victims = [v for v in range(B) if slots[v] is not None
                               and self._table.shard_of(v) == g]
                    victim = max(victims, key=lambda v: slots[v].req.order)
                    if victim == s and len(victims) == 1:
                        raise RuntimeError(   # pragma: no cover
                            "paged cache: resident-page budget exhausted "
                            "with a single live slot in its shard — "
                            "unreachable when every per-shard extent "
                            "covers one full slot")
                    suspend(victim)
            if cow and telemetry is not None:
                rec = getattr(telemetry, "record_cow", None)
                if rec is not None:
                    for _, layer_tokens in cow:
                        rec(layer_tokens)

        def admit():
            nonlocal cache
            for s in range(B):
                while slots[s] is None and (pending or suspended):
                    if suspended:
                        # resume FIFO before admitting new work; if the
                        # oldest suspension cannot fit yet, wait for
                        # pages (live slots will retire) rather than
                        # admitting page-hungry new requests around it.
                        sp = suspended[0]
                        if not self._table.can_restore(sp.payload, s):
                            break
                        suspended.popleft()
                        cache = self._table.restore(cache, s, sp.payload)
                        note_pages(s)   # restore writes the new pages
                        st = _Slot(sp.req, pos=sp.pos, first_token=0)
                        st.out, st.emitted = sp.out, sp.emitted
                        st.feed = sp.feed
                        occupy(s, st, sp.next_tok)
                        if telemetry is not None:
                            telemetry.record_page_in(sp.payload.tokens)
                        continue
                    req = pending[0]
                    plen = req.prompt.shape[0]
                    keys = req.keys
                    if (paged and sharing is not None
                            and keys.whole in memo
                            and self._table.can_admit_cached(s, plen, keys)):
                        # full skip: the exact prompt prefilled earlier
                        # and every page is still registered — attach it
                        # all, restore recurrent state from the host
                        # snapshot, and replay the memoized first-token
                        # logits (bit-identical: both round trips are
                        # exact).  No prefill executable runs.
                        pending.popleft()
                        mlogits, msnap, _ = memo[keys.whole]
                        cache = self._table.admit_cached(
                            cache, s, plen, keys, msnap)
                        note_pages(s)
                        adm = self._table.last_admit
                        if telemetry is not None:
                            rec = getattr(telemetry, "record_admit_shared",
                                          None)
                            if rec is not None:
                                rec(plen, adm["attached_layer_tokens"],
                                    adm["total_layer_tokens"],
                                    skipped_prefill=True)
                        key = self._keys(base,
                                         np.asarray([req.req_id], np.int32),
                                         np.zeros((1,), np.int32))
                        first = int(np.asarray(sample(
                            jnp.asarray(mlogits), key,
                            np.asarray([req.temperature], np.float32),
                            np.asarray([req.top_k], np.int32)))[0])
                        st = _Slot(req, pos=plen, first_token=first)
                        occupy(s, st, first)
                        if finished(st, first):
                            retire(s)
                        continue
                    if paged and not self._table.can_admit(plen, s, keys):
                        break                # wait for pages to free
                    if paged and sharing is not None and sharing.suffix_feed:
                        k = self._table.joint_prefix_pages(s, keys, plen)
                        if k > 0:
                            # opt-in suffix feed (attention-only):
                            # attach the resident prefix pages and
                            # teacher-force the novel suffix through
                            # the decode step — no prefill, no new
                            # executables, tolerance-level (not
                            # bitwise) parity with the prefill path.
                            pending.popleft()
                            ktok = k * self.paged.page_size
                            cache = self._table.attach_prefix(
                                cache, s, keys, k)
                            note_pages(s)
                            adm = self._table.last_admit
                            if telemetry is not None:
                                rec = getattr(telemetry,
                                              "record_admit_shared", None)
                                if rec is not None:
                                    rec(plen, adm["attached_layer_tokens"],
                                        adm["total_layer_tokens"],
                                        suffix_feed=True)
                            st = _Slot(req, pos=ktok, first_token=0)
                            st.out, st.emitted = [], 0
                            st.feed = collections.deque(
                                int(t) for t in req.prompt[ktok + 1:])
                            occupy(s, st, int(req.prompt[ktok]))
                            continue
                    pending.popleft()
                    bucket = self.buckets.bucket_for(plen)
                    padded = np.zeros((1, bucket), np.int32)
                    padded[0, :plen] = req.prompt
                    t0 = time.perf_counter()
                    logits, one = self._prefill(
                        self.params, jnp.asarray(padded),
                        jnp.asarray([plen], jnp.int32))
                    if paged:
                        if sharing is not None:
                            cache = self._table.admit(cache, one, s, plen,
                                                      keys)
                            adm = self._table.last_admit
                            if telemetry is not None:
                                rec = getattr(telemetry,
                                              "record_admit_shared", None)
                                if rec is not None:
                                    rec(plen, adm["attached_layer_tokens"],
                                        adm["total_layer_tokens"])
                            if (sharing.memo_size > 0
                                    and self._table.fully_shareable(plen)
                                    and keys.whole not in memo):
                                memo[keys.whole] = (
                                    np.asarray(logits),
                                    self._table.state_snapshot(one), plen)
                                while len(memo) > sharing.memo_size:
                                    memo.pop(next(iter(memo)))
                        else:
                            cache = self._table.admit(cache, one, s, plen)
                        note_pages(s)   # admission scatters the prefill
                    else:
                        cache = self._insert(cache, one,
                                             jnp.asarray(s, jnp.int32))
                    key = self._keys(base, np.asarray([req.req_id], np.int32),
                                     np.zeros((1,), np.int32))
                    first = int(np.asarray(sample(
                        logits, key,
                        np.asarray([req.temperature], np.float32),
                        np.asarray([req.top_k], np.int32)))[0])
                    self.buckets.record(plen, bucket)
                    if telemetry is not None:
                        telemetry.record_prefill(
                            plen, time.perf_counter() - t0, padded_len=bucket)
                    st = _Slot(req, pos=plen, first_token=first)
                    occupy(s, st, first)
                    if finished(st, first):
                        retire(s)           # keep admitting into this slot

        admit()
        while any(st is not None for st in slots) or suspended or pending:
            if all(st is None for st in slots):
                admit()
                if all(st is None for st in slots):  # pragma: no cover
                    raise RuntimeError(
                        "serve stalled: no slot admissible — resident-page "
                        "budget cannot hold any pending/suspended request")
            if paged:
                grow()
            active = [s for s in range(B) if slots[s] is not None]
            ctx = [int(pos_vec[s]) + 1 for s in active]
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tok_vec),
                                         jnp.asarray(pos_vec))
            keys = self._keys(base, req_vec, emit_vec)
            toks = np.asarray(sample(logits, keys, jnp.asarray(temp_vec),
                                     jnp.asarray(topk_vec)))
            if telemetry is not None:
                telemetry.record_decode(ctx, time.perf_counter() - t0)
            if trace is not None:
                # one trace step per decode step: every active slot's
                # resident pages (allocate-on-write: residency == the
                # context this step's KV sweep reads; the append lands
                # in the same set after grow()) plus whatever moved
                # between steps, with the weights re-streamed.
                for s in active:
                    note_pages(s)
                trace.record_step(pending_pages, param_read=True)
                pending_pages.clear()
            for s in active:
                st = slots[s]
                if st.feed is not None and st.feed:
                    # suffix feed: this step consumed a prompt token;
                    # its sampled draw is discarded (emit_vec stays 0,
                    # so the eventual first token still uses sampling
                    # key (request, 0)) and the next prompt token rides
                    # the next step.
                    st.pos += 1
                    tok_vec[s], pos_vec[s] = st.feed.popleft(), st.pos
                    continue
                st.feed = None   # last fed step falls through: its
                token = int(toks[s])   # draw IS the first emitted token
                st.out.append(token)
                st.emitted += 1
                st.pos += 1
                tok_vec[s], pos_vec[s], emit_vec[s] = token, st.pos, st.emitted
                if finished(st, token):
                    retire(s)
            admit()
        if trace is not None and pending_pages:
            # trailing page moves with no decode step after them (e.g. a
            # final admission that retired on its prefill token)
            trace.record_step(pending_pages, param_read=False)
        return outputs  # type: ignore[return-value]

    # -------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, n_new: int,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0, eos_id: Optional[int] = None) -> np.ndarray:
        """prompts: [b, prompt_len] int32 -> [b, n_new] int32.

        Batch-API wrapper over :meth:`serve`; sequences that retire
        early are right-padded with the EOS id, or with -1 (never a
        valid vocab id) when no EOS is configured — cache-exhaustion
        truncation must stay distinguishable from generated tokens.
        """
        prompts = np.asarray(prompts, np.int32)
        outs = self.serve(list(prompts), n_new, temperature=temperature,
                          top_k=top_k, seed=seed, eos_id=eos_id)
        eos = self.eos_id if eos_id is None else eos_id
        pad = eos if eos is not None else -1
        full = np.full((len(outs), n_new), pad, np.int32)
        for i, o in enumerate(outs):
            full[i, :o.shape[0]] = o
        return full
