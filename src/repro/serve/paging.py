"""Block-table page management for the serving cache.

The model layer defines *what* a paged cache is
(:class:`repro.models.attention.PagedKVCache` and the recurrent-state
mirrors); this module owns the page *lifecycle* the paper's energy
model cares about: which pages are resident, which logical rows they
hold, and every byte that crosses the accelerator boundary when they
move.

One :class:`PageTable` manages every cache stream of a model — one KV
stream per attention pattern position (``groups``/``tail``), one
state-page stream per recurrent (ssm/rglru) position — so all 10
architectures serve through the same allocator:

* **allocate-on-write** — admission takes exactly the pages the
  prompt's rows need (``ceil(min(plen, cache_len)/page_size)`` per KV
  stream, one state page per recurrent stream); decode allocates a
  fresh zeroed page only when a slot's write position crosses into an
  unassigned logical page, so a slot's footprint tracks its actual
  context, not ``max_ctx``.
* **free-on-retire** — a retired slot's pages return to the free list
  and its block-table rows point back at the DUMP page.
* **offload / restore** — a preempted slot's resident pages are copied
  to host memory (:func:`jax.device_put` to the CPU backend), freed on
  device, and later restored bit-identically into freshly allocated
  pages (the block table re-targets; content is unchanged).  The
  engine accounts both directions as page-in/page-out traffic
  (:mod:`repro.serve.telemetry`).

Per-stream pool capacity is ``resident_pages`` + the reserved pages
(ZERO, DUMP — :mod:`repro.models.attention`).  ``resident_pages`` must
cover one fully decoded slot (``max(n_logical_pages)`` over streams):
with that floor, preempting down to a single live slot always frees
enough pages, so the engine can guarantee forward progress under any
budget it accepts.

**Device-local layout (``shards > 1``).**  On a data-parallel mesh the
allocator splits every pool into ``shards`` equal extents — one per
data shard, each fronted by its own ZERO/DUMP pair — and pins batch
slot ``s`` to extent ``s // (max_batch/shards)``, exactly the rows a
``P(data)`` slot layout places on that device.  Allocation then runs a
*per-(stream, shard)* free list: a slot only ever receives pages from
its own extent, so the ``shard_map`` decode step
(:func:`repro.serve.engine.build_decode_step`) reads and writes pool
pages strictly device-locally and no collective with a pool operand is
lowered at any mesh size (the drained ``pool-collective`` baseline
family of ``repro.analysis``).  All budget floors become per-shard:
every shard must hold one fully decoded slot.  ``shards == 1`` is the
original single-pool allocator, bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (DUMP_PAGE, RESERVED_PAGES, ZERO_PAGE,
                                    KVCache, PagedKVCache, n_logical_pages,
                                    paged_kv_view)
from repro.models.rglru import PagedRGLRUCache, RGLRUCache
from repro.models.ssm import PagedSSMCache, SSMCache
from repro.models.transformer import TransformerLM

__all__ = ["PagedCacheConfig", "PageTable", "PagePayload", "PageTableError",
           "logical_view", "slot_floor"]


class PageTableError(RuntimeError):
    """Allocator-invariant violation inside :class:`PageTable` — raised
    with the slot, stream, and live-slot set named so an engine bug
    surfaces as a diagnosable serving error, not a bare ``KeyError``."""


def slot_floor(cfg, max_ctx: int, page_size: int) -> int:
    """Pages one fully decoded slot needs in its largest KV stream —
    THE budget floor: ``resident_pages`` below this can deadlock with
    every other slot already offloaded.  Single source of the rule for
    both the eager :meth:`PagedCacheConfig.validate` and
    :class:`PageTable`'s own defense."""
    floor = 1
    for kind in cfg.all_kinds:
        if kind in ("global", "local"):
            L = cfg.decode_cache_len(kind, max_ctx)
            floor = max(floor, n_logical_pages(L, page_size))
    return floor


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Engine-facing knobs of the paged cache.

    ``page_size``       — tokens per KV page (the paper's mapping-policy
                          granularity: one page == one unit of DRAM-row
                          placement and of offload traffic).
    ``resident_pages``  — device-resident page budget per KV stream
                          (excl. the 2 reserved pages).  When live slots
                          need more, the engine preempts a victim and
                          offloads its pages to host.
    ``max_ctx``         — logical context capacity per slot; ``None``
                          means the engine's ``max_len``.  May exceed
                          ``max_len``: decode keeps appending pages past
                          the prefill cap, which is how requests outgrow
                          the old contiguous per-slot allocation.
    ``state_pages``     — pool extent per recurrent *state* stream,
                          including the reserved pages (``None`` =
                          ``max_batch + shards * RESERVED_PAGES``, the
                          minimum that can hold every slot).  State
                          pools shard their page dim across the data
                          axes exactly like KV pools, but only when the
                          extent divides the axis — on a mesh, size
                          this like ``resident_pages`` (a per-device
                          share times the device count) or the pool
                          replicates and the per-device state bill
                          grows with the mesh.
    ``shards``          — device-local pool extents to build
                          (:mod:`repro.serve.paging` layout note).
                          The default 1 lets the engine auto-resolve
                          from its mesh's data extent
                          (:meth:`repro.dist.sharding.ShardingPolicy.decode_shards`);
                          set it explicitly to build a mesh-shaped
                          cache geometry on a different (e.g. solo
                          compile-only) mesh, as the partitioning
                          auditor does.

    Field-local constraints are checked at construction; the
    cross-field budget floor (``resident_pages`` must hold one fully
    decoded slot, which needs the model's layer mix) is checked by
    :meth:`validate`, which the engine calls before lowering anything —
    a bad config fails eagerly with the offending field named instead
    of deep inside :class:`PageTable`.
    """

    page_size: int = 16
    resident_pages: Optional[int] = None
    max_ctx: Optional[int] = None
    state_pages: Optional[int] = None
    shards: int = 1

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError(
                f"PagedCacheConfig.shards must be >= 1 (device-local pool "
                f"extents), got {self.shards}")
        if self.resident_pages is not None and self.resident_pages % self.shards:
            raise ValueError(
                f"PagedCacheConfig.resident_pages={self.resident_pages} must "
                f"split evenly across shards={self.shards} device-local "
                f"extents")
        if self.state_pages is not None and self.state_pages % self.shards:
            raise ValueError(
                f"PagedCacheConfig.state_pages={self.state_pages} must split "
                f"evenly across shards={self.shards} device-local extents")
        if self.page_size < 1:
            raise ValueError(
                f"PagedCacheConfig.page_size must be > 0 (tokens per KV "
                f"page), got {self.page_size}")
        if self.resident_pages is not None and self.resident_pages < 1:
            raise ValueError(
                f"PagedCacheConfig.resident_pages must be >= 1 when set "
                f"(device page budget per KV stream), got "
                f"{self.resident_pages}")
        if self.state_pages is not None and self.state_pages < 1:
            raise ValueError(
                f"PagedCacheConfig.state_pages must be >= 1 when set "
                f"(state-stream pool extent incl. reserved pages), got "
                f"{self.state_pages}")
        if self.max_ctx is not None and self.max_ctx < 1:
            raise ValueError(
                f"PagedCacheConfig.max_ctx must be >= 1 when set "
                f"(logical context capacity per slot), got {self.max_ctx}")

    def slot_floor(self, cfg, max_ctx: int) -> int:
        """Pages one fully decoded slot needs in its largest KV stream
        (the guaranteed-progress floor for ``resident_pages``)."""
        return slot_floor(cfg, max_ctx, self.page_size)

    def validate(self, cfg, max_ctx: Optional[int] = None) -> None:
        """Cross-field checks against a model config (and the engine's
        resolved ``max_ctx``, defaulting to this config's own)."""
        ctx = int(max_ctx if max_ctx is not None else (self.max_ctx or 0))
        if ctx < 1:
            raise ValueError(
                "PagedCacheConfig.validate needs a positive max_ctx "
                "(none set on the config and none passed)")
        floor = self.slot_floor(cfg, ctx)
        if (self.resident_pages is not None
                and self.resident_pages // self.shards < floor):
            per = (f" per shard ({self.shards} device-local extents)"
                   if self.shards > 1 else "")
            raise ValueError(
                f"PagedCacheConfig.resident_pages={self.resident_pages} "
                f"cannot hold one fully decoded slot{per}: max_ctx={ctx} at "
                f"page_size={self.page_size} needs {floor} pages in the "
                f"largest KV stream; the engine could deadlock with every "
                f"other slot already offloaded")


class _Stream:
    """Host-side allocator state of one cache stream.

    ``free`` is one free list *per data shard*: ``free[g]`` holds only
    global page ids inside shard ``g``'s pool extent
    ``[g*ext, (g+1)*ext)``, whose first ``RESERVED_PAGES`` ids are that
    shard's private ZERO/DUMP pair (:meth:`zero` / :meth:`dump`)."""

    __slots__ = ("where", "kind", "cache_len", "n_lp", "n_pages", "shards",
                 "ext", "free", "slot_pages")

    def __init__(self, where, kind, cache_len, n_lp, n_pages, shards=1):
        self.where = where            # ("groups", i) | ("tail", i)
        self.kind = kind
        self.cache_len = cache_len    # None for state streams
        self.n_lp = n_lp              # logical pages (1 for state streams)
        self.n_pages = n_pages        # pool extent incl. reserved pages
        self.shards = shards
        assert n_pages % shards == 0, (where, n_pages, shards)
        self.ext = n_pages // shards  # per-shard pool extent
        self.free: List[List[int]] = []
        self.reset_free()
        # KV: {slot: {jdx: pid}}; state: {slot: pid}
        self.slot_pages: Dict[int, object] = {}

    def reset_free(self) -> None:
        self.free = [list(range(g * self.ext + RESERVED_PAGES,
                                (g + 1) * self.ext))
                     for g in range(self.shards)]

    def zero(self, g: int) -> int:
        """Global id of shard ``g``'s ZERO page."""
        return g * self.ext + ZERO_PAGE

    def dump(self, g: int) -> int:
        """Global id of shard ``g``'s DUMP page."""
        return g * self.ext + DUMP_PAGE

    @property
    def is_state(self) -> bool:
        return self.cache_len is None


@dataclasses.dataclass
class PagePayload:
    """Host-resident copy of one offloaded slot (all streams).

    ``kv[si] = (jdx->row, k_pages, v_pages)`` with contents shaped
    ``[G?, n_rows, page_size, kv_heads, head_dim]``;
    ``state[si] = (conv, h)``.  ``tokens`` is the slot's context length
    at offload time (for traffic accounting).
    """

    kv: Dict[int, Tuple[Dict[int, int], np.ndarray, np.ndarray]]
    state: Dict[int, Tuple[np.ndarray, np.ndarray]]
    tokens: int

    def pages_needed(self) -> Dict[int, int]:
        return {si: len(jdx_rows) for si, (jdx_rows, _, _) in self.kv.items()}


class PageTable:
    """Page allocator + jitted cache-update ops for one engine.

    All device-side mutation goes through jitted functions whose cache
    output can be pinned to the decode step's shardings
    (``cache_shardings``), so the admit/decode/offload round trip stays
    layout-stable on real meshes.
    """

    def __init__(self, model: TransformerLM, max_batch: int, max_ctx: int,
                 page_size: int, resident_pages: Optional[int] = None,
                 cache_shardings=None, state_pages: Optional[int] = None,
                 shards: int = 1):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.model = model
        self.cfg = model.cfg
        self.max_batch = int(max_batch)
        self.max_ctx = int(max_ctx)
        self.page_size = int(page_size)
        self.shards = int(shards)
        if self.max_batch % self.shards:
            raise ValueError(
                f"max_batch={self.max_batch} slots cannot pin evenly to "
                f"shards={self.shards} device-local pool extents (slots "
                f"ride the data axes in contiguous blocks)")
        self.slots_per_shard = self.max_batch // self.shards
        self._csh = cache_shardings

        self.streams: List[_Stream] = []
        min_budget = slot_floor(self.cfg, self.max_ctx, self.page_size)
        if resident_pages is None:
            # ample default: every slot fully decoded stays resident
            resident_pages = min_budget * self.max_batch
        if resident_pages % self.shards:
            raise ValueError(
                f"resident_pages={resident_pages} must split evenly across "
                f"shards={self.shards} device-local extents")
        if resident_pages // self.shards < min_budget:
            per = (f" in each of the {self.shards} device-local extents"
                   if self.shards > 1 else "")
            raise ValueError(
                f"resident_pages={resident_pages} cannot hold one fully "
                f"decoded slot{per} ({min_budget} pages of {page_size} "
                f"tokens for max_ctx={self.max_ctx}); the engine could "
                f"deadlock with every other slot already offloaded")
        self.resident_pages = int(resident_pages)
        # every shard carries its own reserved ZERO/DUMP pair
        self.n_pages = self.resident_pages + self.shards * RESERVED_PAGES

        state_floor = self.max_batch + self.shards * RESERVED_PAGES
        if state_pages is None:
            state_pages = state_floor
        if state_pages % self.shards:
            raise ValueError(
                f"state_pages={state_pages} must split evenly across "
                f"shards={self.shards} device-local extents")
        if state_pages < state_floor:
            raise ValueError(
                f"state_pages={state_pages} cannot hold every slot's "
                f"recurrent state: max_batch={self.max_batch} slots need "
                f"{state_floor} pages (one each plus {RESERVED_PAGES} "
                f"reserved per shard x {self.shards} shard(s))")
        self.state_pages = int(state_pages)

        for where, kind in self._positions():
            if kind in ("global", "local"):
                L = self.cfg.decode_cache_len(kind, self.max_ctx)
                self.streams.append(_Stream(
                    where, kind, L, n_logical_pages(L, page_size),
                    self.n_pages, self.shards))
            else:
                self.streams.append(_Stream(
                    where, kind, None, 1, self.state_pages, self.shards))

        self.bind_shardings(cache_shardings)

    def shard_of(self, slot: int) -> int:
        """Data shard (pool extent) batch slot ``slot`` is pinned to."""
        return int(slot) // self.slots_per_shard

    def bind_shardings(self, cache_shardings=None) -> None:
        """(Re)build the jitted cache ops, pinning their cache output to
        ``cache_shardings`` (the decode step's) so the admit/decode/
        offload round trip is layout-stable on real meshes.  The engine
        calls this once the decode step — and therefore the cache
        placement — exists."""
        self._csh = cache_shardings
        # donate the cache arg (as the decode step does): these ops
        # rewrite a slice of the pools, and without donation each admit/
        # retire/page-assign would copy every pool buffer on device.
        # fetch must NOT donate — offload reads pages out of a cache
        # that stays live.
        kw = {"donate_argnums": (0,)}
        if cache_shardings is not None:
            kw["out_shardings"] = cache_shardings
        self._insert_jit = jax.jit(self._insert_fn, **kw)
        self._release_jit = jax.jit(self._release_fn, **kw)
        self._restore_jit = jax.jit(self._restore_fn, **kw)
        self._assign_jit = {
            si: jax.jit(lambda c, s, j, p, _si=si: self._assign_fn(_si, c, s, j, p),
                        **kw)
            for si, st in enumerate(self.streams) if not st.is_state}
        self._fetch_jit = {
            si: (jax.jit(lambda c, pid, _si=si: self._fetch_state_fn(_si, c, pid))
                 if st.is_state else
                 jax.jit(lambda c, ids, _si=si: self._fetch_kv_fn(_si, c, ids)))
            for si, st in enumerate(self.streams)}

    def reset(self) -> None:
        """Drop all allocations (fresh serve call: every page free)."""
        for st in self.streams:
            st.reset_free()
            st.slot_pages.clear()

    # ------------------------------------------------------------- structure
    def _positions(self):
        for i, kind in enumerate(self.cfg.attn_pattern):
            yield ("groups", i), kind
        for i, kind in enumerate(self.cfg.pattern_tail):
            yield ("tail", i), kind

    def _get(self, cache, where):
        return cache[where[0]][where[1]]

    @staticmethod
    def _replace(cache, where, node):
        top, i = where
        seq = list(cache[top])
        seq[i] = node
        return {**cache, top: tuple(seq)}

    def init_cache(self):
        return self.model.init_paged_cache(
            self.max_batch, self.max_ctx, self.page_size, self.n_pages,
            state_pages=self.state_pages, shards=self.shards)

    # -------------------------------------------------------------- sizing
    def kv_pages_for(self, tokens: int, stream: _Stream) -> int:
        """Pages prefilling ``tokens`` prompt rows writes in a stream
        (a prompt past the ring length wraps and touches every page)."""
        return n_logical_pages(
            min(max(int(tokens), 1), stream.cache_len), self.page_size)

    def can_admit(self, plen: int, slot: int) -> bool:
        """Whether ``slot``'s shard has pages for a ``plen``-token
        prompt in every stream (allocation is strictly shard-local)."""
        g = self.shard_of(slot)
        for st in self.streams:
            need = 1 if st.is_state else self.kv_pages_for(plen, st)
            if len(st.free[g]) < need:
                return False
        return True

    def free_page_counts(self) -> Dict[Tuple[str, int], int]:
        return {st.where: sum(len(f) for f in st.free)
                for st in self.streams}

    # --------------------------------------------------- placement geometry
    _ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}

    def stream_name(self, si: int) -> str:
        st = self.streams[si]
        return f"{'state' if st.is_state else 'kv'}:{st.where[0]}{st.where[1]}"

    def stream_names(self) -> Tuple[str, ...]:
        """Stable stream labels, in stream-list order — the binding
        contract between a :class:`repro.core.trace.PageAccessTrace`
        and the :class:`repro.core.placement.StreamGeometry` set."""
        return tuple(self.stream_name(si) for si in range(len(self.streams)))

    def stream_geometries(self, cfg=None):
        """Per-stream :class:`repro.core.placement.StreamGeometry` —
        the DRAM shape of this table's pools.

        A ``("groups", i)`` stream's page id indexes ``n_groups``
        stacked per-layer pool pages at once (``init_paged_cache``
        broadcasts the group's layers over one leading axis), so its
        placement page carries the group's whole stack of bytes.

        ``cfg`` overrides the model config for sizing (e.g. the full
        arch while the engine serves the smoke twin); it must share the
        smoke config's attn_pattern/pattern_tail structure or the
        stream list would not line up.
        """
        from repro.core.placement import StreamGeometry

        mcfg = self.cfg if cfg is None else cfg
        if cfg is not None and (
                tuple(mcfg.attn_pattern) != tuple(self.cfg.attn_pattern)
                or tuple(mcfg.pattern_tail) != tuple(self.cfg.pattern_tail)):
            raise ValueError(
                f"stream_geometries: override config {mcfg.name!r} has "
                f"pattern {mcfg.attn_pattern}/{mcfg.pattern_tail} but the "
                f"table was built for {self.cfg.attn_pattern}/"
                f"{self.cfg.pattern_tail}")
        isz = self._ITEMSIZE[mcfg.dtype]
        geoms = []
        for si, st in enumerate(self.streams):
            if st.is_state:
                if st.kind == "ssm":
                    pb = ((mcfg.ssm_conv - 1) * mcfg.d_inner * isz
                          + mcfg.d_inner * mcfg.ssm_state * 4)
                else:   # rglru: f32 hidden state rides beside the conv tap
                    pb = ((mcfg.conv1d_width - 1) * mcfg.resolved_lru_width
                          * isz + mcfg.resolved_lru_width * 4)
            else:
                pb = (2 * self.page_size * mcfg.n_kv_heads
                      * mcfg.resolved_head_dim * isz)
            if st.where[0] == "groups":
                pb *= mcfg.n_groups
            geoms.append(StreamGeometry(
                name=self.stream_name(si), n_pages=st.n_pages,
                page_bytes=int(pb), shards=st.shards,
                reserved_per_shard=RESERVED_PAGES))
        return tuple(geoms)

    def slot_page_ids(self, slot: int) -> List[Tuple[int, Tuple[int, ...]]]:
        """Physical pages ``slot`` holds right now, per stream — the
        page set one decode step reads AND writes (allocate-on-write:
        a resident page exists only because the slot's context reaches
        into it, and the KV gather sweeps every resident page)."""
        out = []
        for si, st in enumerate(self.streams):
            held = st.slot_pages.get(slot)
            if held is None:
                continue
            pids = (held,) if st.is_state else tuple(held.values())
            if pids:
                out.append((si, pids))
        return out

    # ------------------------------------------------------------ jitted ops
    def _insert_fn(self, cache, one, slot, pages, zeros, dumps):
        """Scatter a prefilled batch-1 contiguous cache into this
        slot's freshly assigned pages.  ``pages`` mirrors the stream
        list: KV entries are ``[n_lp]`` int32 page ids (-1 = logical
        page left unallocated -> block points at the slot's shard's
        ZERO), state entries are scalar int32 page ids.  ``zeros`` /
        ``dumps`` are the per-stream reserved-page ids of the slot's
        shard, passed traced so one compile serves every slot."""
        for si, st in enumerate(self.streams):
            pc, oc = self._get(cache, st.where), self._get(one, st.where)
            grouped = st.where[0] == "groups"
            if st.is_state:
                pc = self._ins_state(pc, oc, slot, pages[si], grouped)
            else:
                pc = self._ins_kv(pc, oc, slot, pages[si], grouped,
                                  zeros[si], dumps[si])
            cache = self._replace(cache, st.where, pc)
        return cache

    def _ins_kv(self, pc: PagedKVCache, oc: KVCache, slot, pids, grouped,
                zero, dump):
        P, L = pc.page_size, pc.cache_len
        n_lp = pids.shape[0]
        write_ids = jnp.where(pids < 0, dump, pids)
        pad = n_lp * P - L

        def scat(pool, rows):            # rows: [L, kvh, hd]
            src = jnp.pad(rows, ((0, pad), (0, 0), (0, 0)))
            return pool.at[write_ids].set(
                src.reshape((n_lp, P) + rows.shape[1:]))

        block_row = jnp.where(pids < 0, zero, pids)
        if grouped:
            kp = jax.vmap(scat)(pc.kp, oc.k[:, 0])
            vp = jax.vmap(scat)(pc.vp, oc.v[:, 0])
            block = pc.block.at[:, slot].set(block_row)
        else:
            kp = scat(pc.kp, oc.k[0])
            vp = scat(pc.vp, oc.v[0])
            block = pc.block.at[slot].set(block_row)
        return dataclasses.replace(
            pc, kp=kp, vp=vp, block=block,
            length=jnp.maximum(pc.length, oc.length))

    def _ins_state(self, pc, oc, slot, pid, grouped):
        if grouped:
            return dataclasses.replace(
                pc,
                conv_p=pc.conv_p.at[:, pid].set(oc.conv[:, 0]),
                h_p=pc.h_p.at[:, pid].set(oc.h[:, 0]),
                block=pc.block.at[:, slot].set(pid))
        return dataclasses.replace(
            pc,
            conv_p=pc.conv_p.at[pid].set(oc.conv[0]),
            h_p=pc.h_p.at[pid].set(oc.h[0]),
            block=pc.block.at[slot].set(pid))

    def _release_fn(self, cache, slot, dumps):
        """Point every block-table row of ``slot`` back at its shard's
        DUMP page (``dumps``: per-stream traced ids)."""
        for si, st in enumerate(self.streams):
            pc = self._get(cache, st.where)
            grouped = st.where[0] == "groups"
            if grouped:
                block = pc.block.at[:, slot].set(dumps[si])
            else:
                block = pc.block.at[slot].set(dumps[si])
            cache = self._replace(cache, st.where,
                                  dataclasses.replace(pc, block=block))
        return cache

    def _assign_fn(self, si, cache, slot, jdx, pid):
        """Assign a zeroed page to logical page ``jdx`` of ``slot``
        (decode growth: allocate-on-write at a page boundary)."""
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            pc = dataclasses.replace(
                pc,
                kp=pc.kp.at[:, pid].set(0),
                vp=pc.vp.at[:, pid].set(0),
                block=pc.block.at[:, slot, jdx].set(pid))
        else:
            pc = dataclasses.replace(
                pc,
                kp=pc.kp.at[pid].set(0),
                vp=pc.vp.at[pid].set(0),
                block=pc.block.at[slot, jdx].set(pid))
        return self._replace(cache, st.where, pc)

    def _fetch_kv_fn(self, si, cache, ids):
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            return pc.kp[:, ids], pc.vp[:, ids]
        return pc.kp[ids], pc.vp[ids]

    def _fetch_state_fn(self, si, cache, pid):
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            return pc.conv_p[:, pid], pc.h_p[:, pid]
        return pc.conv_p[pid], pc.h_p[pid]

    def _restore_fn(self, cache, slot, payload):
        """Write offloaded page contents into freshly assigned pages.
        ``payload`` mirrors the stream list: KV entries are
        ``(pids [n_rows], jdxs [n_rows], k_pages, v_pages)`` (pids
        already allocated), state entries ``(pid, conv, h)``."""
        for si, st in enumerate(self.streams):
            pc = self._get(cache, st.where)
            grouped = st.where[0] == "groups"
            if st.is_state:
                pid, conv, h = payload[si]
                if grouped:
                    pc = dataclasses.replace(
                        pc,
                        conv_p=pc.conv_p.at[:, pid].set(conv),
                        h_p=pc.h_p.at[:, pid].set(h),
                        block=pc.block.at[:, slot].set(pid))
                else:
                    pc = dataclasses.replace(
                        pc,
                        conv_p=pc.conv_p.at[pid].set(conv),
                        h_p=pc.h_p.at[pid].set(h),
                        block=pc.block.at[slot].set(pid))
            else:
                pids, jdxs, kpg, vpg = payload[si]
                if grouped:
                    pc = dataclasses.replace(
                        pc,
                        kp=pc.kp.at[:, pids].set(kpg),
                        vp=pc.vp.at[:, pids].set(vpg),
                        block=pc.block.at[:, slot, jdxs].set(pids))
                else:
                    pc = dataclasses.replace(
                        pc,
                        kp=pc.kp.at[pids].set(kpg),
                        vp=pc.vp.at[pids].set(vpg),
                        block=pc.block.at[slot, jdxs].set(pids))
            cache = self._replace(cache, st.where, pc)
        return cache

    # ----------------------------------------------------------- operations
    def _reserved_ids(self, slot: int):
        """Per-stream (zeros, dumps) traced scalars of ``slot``'s shard,
        for the jitted ops that re-target dead block rows."""
        g = self.shard_of(slot)
        zeros = tuple(jnp.asarray(st.zero(g), jnp.int32)
                      for st in self.streams)
        dumps = tuple(jnp.asarray(st.dump(g), jnp.int32)
                      for st in self.streams)
        return zeros, dumps

    def admit(self, cache, one, slot: int, plen: int):
        """Allocate pages (from ``slot``'s shard extent) for a freshly
        prefilled request and scatter its contiguous batch-1 cache into
        them."""
        g = self.shard_of(slot)
        pages = []
        for st in self.streams:
            if st.is_state:
                pid = st.free[g].pop()
                st.slot_pages[slot] = pid
                pages.append(jnp.asarray(pid, jnp.int32))
            else:
                need = self.kv_pages_for(plen, st)
                pids = [st.free[g].pop() for _ in range(need)]
                st.slot_pages[slot] = dict(enumerate(pids))
                vec = np.full((st.n_lp,), -1, np.int32)
                vec[:need] = pids
                pages.append(jnp.asarray(vec))
        zeros, dumps = self._reserved_ids(slot)
        return self._insert_jit(cache, one, jnp.asarray(slot, jnp.int32),
                                tuple(pages), zeros, dumps)

    def release(self, cache, slot: int):
        """Free a retired slot's pages; its block rows return to DUMP."""
        g = self.shard_of(slot)
        for st in self.streams:
            held = st.slot_pages.pop(slot, None)
            if held is None:
                continue
            st.free[g].extend([held] if st.is_state else held.values())
        _, dumps = self._reserved_ids(slot)
        return self._release_jit(cache, jnp.asarray(slot, jnp.int32), dumps)

    def prepare_step(self, cache, slot: int, pos: int):
        """Ensure the page each KV stream will write at ``pos`` is
        assigned (from ``slot``'s shard extent).  Returns
        ``(cache, ok)``; ``ok`` is False when a pool is exhausted (the
        engine must preempt a victim and retry).

        Invariant — *partial progress is committed*: page assignments
        for streams visited before the exhausted one stay in the cache
        and in ``slot_pages`` even on the ``ok=False`` return.  That is
        deliberate and safe: an assigned page is recorded under its
        ``jdx``, so the post-preemption retry skips it (``jdx in
        held``) and only allocates the still-missing streams, and the
        page content is all-zeros until the decode step actually writes
        through the block table — generations are bit-identical to a
        serve that never exhausted the pool
        (``tests/test_paged_cache.py`` pins this).  Callers must not
        assume the cache is untouched when ``ok`` is False."""
        g = self.shard_of(slot)
        for si, st in enumerate(self.streams):
            if st.is_state:
                continue
            jdx = (pos % st.cache_len) // self.page_size
            held = st.slot_pages[slot]
            if jdx in held:
                continue
            if not st.free[g]:
                return cache, False
            pid = st.free[g].pop()
            held[jdx] = pid
            cache = self._assign_jit[si](
                cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(jdx, jnp.int32), jnp.asarray(pid, jnp.int32))
        return cache, True

    def offload(self, cache, slot: int, tokens: int):
        """Copy a slot's resident pages to host, free them on device.

        Returns ``(cache, payload)``.  The host copy is explicit
        (``jax.device_put`` onto the CPU backend), so the content
        round-trips through host memory, not a device alias.
        """
        host = jax.devices("cpu")[0]
        g = self.shard_of(slot)
        kv, state = {}, {}
        for si, st in enumerate(self.streams):
            if slot not in st.slot_pages:
                raise PageTableError(
                    f"offload: slot {slot} holds no pages in stream "
                    f"{st.where} (kind={st.kind!r}); live slots there: "
                    f"{sorted(st.slot_pages)} — offload victims must be "
                    f"admitted slots")
            held = st.slot_pages.pop(slot)
            if st.is_state:
                conv, h = self._fetch_jit[si](cache, jnp.asarray(held, jnp.int32))
                state[si] = (np.asarray(jax.device_put(conv, host)),
                             np.asarray(jax.device_put(h, host)))
                st.free[g].append(held)
            else:
                jdxs = sorted(held)
                ids = jnp.asarray([held[j] for j in jdxs], jnp.int32)
                kpg, vpg = self._fetch_jit[si](cache, ids)
                kv[si] = (dict(zip(jdxs, range(len(jdxs)))),
                          np.asarray(jax.device_put(kpg, host)),
                          np.asarray(jax.device_put(vpg, host)))
                st.free[g].extend(held.values())
        _, dumps = self._reserved_ids(slot)
        cache = self._release_jit(cache, jnp.asarray(slot, jnp.int32), dumps)
        return cache, PagePayload(kv=kv, state=state, tokens=int(tokens))

    def can_restore(self, payload: PagePayload, slot: int) -> bool:
        """Whether ``slot``'s shard has pages for the payload in every
        stream (restore allocates strictly shard-locally, like admit)."""
        g = self.shard_of(slot)
        need = payload.pages_needed()
        for si, st in enumerate(self.streams):
            if len(st.free[g]) < (1 if st.is_state else need[si]):
                return False
        return True

    def restore(self, cache, slot: int, payload: PagePayload):
        """Re-admit an offloaded slot: new pages (from ``slot``'s shard
        extent — any slot/shard, not necessarily the original), same
        bytes."""
        g = self.shard_of(slot)
        args = []
        for si, st in enumerate(self.streams):
            if st.is_state:
                pid = st.free[g].pop()
                st.slot_pages[slot] = pid
                conv, h = payload.state[si]
                args.append((jnp.asarray(pid, jnp.int32),
                             jnp.asarray(conv), jnp.asarray(h)))
            else:
                jdx_rows, kpg, vpg = payload.kv[si]
                jdxs = list(jdx_rows)
                pids = [st.free[g].pop() for _ in range(len(jdxs))]
                st.slot_pages[slot] = dict(zip(jdxs, pids))
                args.append((jnp.asarray(pids, jnp.int32),
                             jnp.asarray(jdxs, jnp.int32),
                             jnp.asarray(kpg), jnp.asarray(vpg)))
        return self._restore_jit(cache, jnp.asarray(slot, jnp.int32),
                                 tuple(args))


# ---------------------------------------------------------------------------
# Test/debug helper
# ---------------------------------------------------------------------------
def logical_view(cache):
    """Resolve a paged cache pytree into the contiguous cache pytree a
    ``model.init_cache`` decode would carry (KVCache/SSMCache/RGLRUCache
    with the same ``{'groups', 'tail'}`` structure).

    The paged==contiguous equivalence suite compares this view bitwise
    against the contiguous engine's cache: values must land in the same
    slot order for attention to be bit-identical.
    """
    def one(node):
        if isinstance(node, PagedKVCache):
            if node.block.ndim == 3:      # grouped: [G, ...] leaves
                k, v = jax.vmap(
                    lambda kp, vp, blk: paged_kv_view(
                        dataclasses.replace(node, kp=kp, vp=vp, block=blk))
                )(node.kp, node.vp, node.block)
            else:
                k, v = paged_kv_view(node)
            return KVCache(k=k, v=v, length=node.length)
        if isinstance(node, PagedSSMCache):
            if node.block.ndim == 2:
                return SSMCache(
                    conv=jax.vmap(lambda c, b: c[b])(node.conv_p, node.block),
                    h=jax.vmap(lambda h, b: h[b])(node.h_p, node.block))
            return SSMCache(conv=node.conv_p[node.block],
                            h=node.h_p[node.block])
        if isinstance(node, PagedRGLRUCache):
            if node.block.ndim == 2:
                return RGLRUCache(
                    conv=jax.vmap(lambda c, b: c[b])(node.conv_p, node.block),
                    h=jax.vmap(lambda h, b: h[b])(node.h_p, node.block))
            return RGLRUCache(conv=node.conv_p[node.block],
                              h=node.h_p[node.block])
        return node

    return {
        top: tuple(one(node) for node in cache[top])
        for top in ("groups", "tail")
    }
