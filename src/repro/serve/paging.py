"""Block-table page management for the serving cache.

The model layer defines *what* a paged cache is
(:class:`repro.models.attention.PagedKVCache` and the recurrent-state
mirrors); this module owns the page *lifecycle* the paper's energy
model cares about: which pages are resident, which logical rows they
hold, and every byte that crosses the accelerator boundary when they
move.

One :class:`PageTable` manages every cache stream of a model — one KV
stream per attention pattern position (``groups``/``tail``), one
state-page stream per recurrent (ssm/rglru) position — so all 10
architectures serve through the same allocator:

* **allocate-on-write** — admission takes exactly the pages the
  prompt's rows need (``ceil(min(plen, cache_len)/page_size)`` per KV
  stream, one state page per recurrent stream); decode allocates a
  fresh zeroed page only when a slot's write position crosses into an
  unassigned logical page, so a slot's footprint tracks its actual
  context, not ``max_ctx``.
* **free-on-retire** — a retired slot's pages return to the free list
  and its block-table rows point back at the DUMP page.
* **offload / restore** — a preempted slot's resident pages are copied
  to host memory (:func:`jax.device_put` to the CPU backend), freed on
  device, and later restored bit-identically into freshly allocated
  pages (the block table re-targets; content is unchanged).  The
  engine accounts both directions as page-in/page-out traffic
  (:mod:`repro.serve.telemetry`).
* **prefix sharing + copy-on-write** (PR 10) — identical prompt
  prefixes hash to the same physical pages.  A KV page's content is a
  pure function of the token prefix up to and including its tokens
  (attention is causal), so one chained content hash per page-granular
  token chunk (:func:`prefix_page_keys`) keys a per-(stream, shard)
  registry of live pages.  Admission attaches registry hits instead of
  allocating: the block-table row points at the shared page, the
  admission scatter for that row is redirected to the shard's DUMP
  page, and the page's refcount rises.  Decode forks a private copy on
  the first write into a shared page (refcount > 1: device-side page
  copy + block re-target; refcount == 1: the sole owner unregisters it
  in place and writes through) — so ring wraps and appends into a
  shared partial tail page stay bit-identical to unshared serving.
  Refcount lifecycle: register-on-admit (refcount 1), +1 per attach,
  -1 on fork/release/offload, unregister + free at zero.  A registered
  page therefore lives exactly as long as one admitted slot still
  references it — sharing is an in-flight property, which is why the
  engine's prefix-aware scheduler batches same-prefix requests.
  Registries are strictly per shard: a slot only ever attaches pages
  inside its own device-local extent, preserving the PR 8 no-pool-
  collective layout.  State (ssm/rglru) pages are rewritten every
  decode step and never shared; the engine's full-prompt memo restores
  them from a host snapshot instead.

Per-stream pool capacity is ``resident_pages`` + the reserved pages
(ZERO, DUMP — :mod:`repro.models.attention`).  ``resident_pages`` must
cover one fully decoded slot (``max(n_logical_pages)`` over streams):
with that floor, preempting down to a single live slot always frees
enough pages, so the engine can guarantee forward progress under any
budget it accepts.

**Device-local layout (``shards > 1``).**  On a data-parallel mesh the
allocator splits every pool into ``shards`` equal extents — one per
data shard, each fronted by its own ZERO/DUMP pair — and pins batch
slot ``s`` to extent ``s // (max_batch/shards)``, exactly the rows a
``P(data)`` slot layout places on that device.  Allocation then runs a
*per-(stream, shard)* free list: a slot only ever receives pages from
its own extent, so the ``shard_map`` decode step
(:func:`repro.serve.engine.build_decode_step`) reads and writes pool
pages strictly device-locally and no collective with a pool operand is
lowered at any mesh size (the drained ``pool-collective`` baseline
family of ``repro.analysis``).  All budget floors become per-shard:
every shard must hold one fully decoded slot.  ``shards == 1`` is the
original single-pool allocator, bit for bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (DUMP_PAGE, RESERVED_PAGES, ZERO_PAGE,
                                    KVCache, PagedKVCache, n_logical_pages,
                                    paged_kv_view)
from repro.models.rglru import PagedRGLRUCache, RGLRUCache
from repro.models.ssm import PagedSSMCache, SSMCache
from repro.models.transformer import TransformerLM

__all__ = ["PagedCacheConfig", "PageTable", "PagePayload", "PageTableError",
           "PrefixSharingConfig", "PrefixKeys", "prefix_page_keys",
           "logical_view", "slot_floor"]


class PageTableError(RuntimeError):
    """Allocator-invariant violation inside :class:`PageTable` — raised
    with the slot, stream, and live-slot set named so an engine bug
    surfaces as a diagnosable serving error, not a bare ``KeyError``."""


def slot_floor(cfg, max_ctx: int, page_size: int) -> int:
    """Pages one fully decoded slot needs in its largest KV stream —
    THE budget floor: ``resident_pages`` below this can deadlock with
    every other slot already offloaded.  Single source of the rule for
    both the eager :meth:`PagedCacheConfig.validate` and
    :class:`PageTable`'s own defense."""
    floor = 1
    for kind in cfg.all_kinds:
        if kind in ("global", "local"):
            L = cfg.decode_cache_len(kind, max_ctx)
            floor = max(floor, n_logical_pages(L, page_size))
    return floor


# ---------------------------------------------------------------------------
# Prefix-sharing keys
# ---------------------------------------------------------------------------
_CHAIN_SEED = b"rtc-prefix-v1"


@dataclasses.dataclass(frozen=True)
class PrefixKeys:
    """Content-addressed page keys of one prompt.

    ``full[j]`` is the chained digest of token pages ``0..j`` — equal
    across two prompts iff their first ``(j+1)*page_size`` tokens are
    equal, so it keys the j-th full KV page in every stream.  ``tail``
    keys the partial last page (chain- and length-sensitive; ``None``
    when the prompt is page-aligned).  ``whole`` digests the entire
    prompt (the engine's full-prompt memo key) and ``group`` is the
    scheduler's batching key (first full page, or ``whole`` for
    prompts shorter than one page).
    """

    full: Tuple[bytes, ...]
    tail: Optional[bytes]
    whole: bytes
    group: bytes


def prefix_page_keys(tokens, page_size: int) -> PrefixKeys:
    """Chain-hash a prompt into per-page content keys.

    ``key_j = H(key_{j-1} || tokens[j*P:(j+1)*P])`` over full pages —
    the vLLM-style chaining that makes a page key identify the whole
    token prefix behind it, not just the page's own tokens (a KV page's
    content depends on every earlier token through causal attention).
    One hash chain serves all cache streams: per-stream registries map
    the same key to their own physical page.
    """
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    toks = np.asarray(tokens, np.int32).reshape(-1)
    chain = hashlib.sha1(_CHAIN_SEED).digest()
    full: List[bytes] = []
    n_full = toks.size // page_size
    for j in range(n_full):
        chain = hashlib.sha1(
            chain + toks[j * page_size:(j + 1) * page_size].tobytes()
        ).digest()
        full.append(chain)
    rem = toks.size - n_full * page_size
    tail = (hashlib.sha1(chain + b"tail"
                         + toks[n_full * page_size:].tobytes()).digest()
            if rem else None)
    whole = tail if tail is not None else (full[-1] if full else chain)
    group = full[0] if full else whole
    return PrefixKeys(full=tuple(full), tail=tail, whole=whole, group=group)


@dataclasses.dataclass(frozen=True)
class PrefixSharingConfig:
    """Prefix-sharing knobs (``PagedCacheConfig.sharing``).

    ``enabled``      — master switch; ``None``/disabled serves exactly
                       the pre-sharing allocator, bit for bit.
    ``schedule``     — pending-queue admission order: ``"prefix"``
                       groups same-prefix requests (group order = first
                       arrival, so FCFS progress is preserved) to
                       maximize in-flight hits; ``"fifo"`` keeps raw
                       arrival order.  Generations are bit-independent
                       of the schedule (sampling keys are (request,
                       token-index)-addressed), only the hit rate moves.
    ``suffix_feed``  — opt-in compute skip for *proper*-prefix hits on
                       attention-only models: attach the cached prefix
                       pages and teacher-force only the novel suffix
                       through the existing decode executable (zero new
                       lowered executables).  Decode-path arithmetic is
                       tolerance-equal, not bitwise-equal, to prefill
                       (~1e-6 logit drift), so this mode trades the
                       bit-identity guarantee for skipped prefill
                       compute — hence opt-in.  The default sharing
                       paths (dedup-attach and the full-prompt memo
                       skip, which replays the memoized prefill logits
                       exactly) stay bit-identical.
    ``memo_size``    — full-prompt memo entries kept per serve call
                       (prefill logits + recurrent-state snapshot,
                       host-resident; FIFO eviction).
    """

    enabled: bool = True
    schedule: str = "prefix"
    suffix_feed: bool = False
    memo_size: int = 64

    def __post_init__(self):
        if self.schedule not in ("prefix", "fifo"):
            raise ValueError(
                f"PrefixSharingConfig.schedule must be 'prefix' or 'fifo', "
                f"got {self.schedule!r}")
        if self.memo_size < 0:
            raise ValueError(
                f"PrefixSharingConfig.memo_size must be >= 0, "
                f"got {self.memo_size}")


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Engine-facing knobs of the paged cache.

    ``page_size``       — tokens per KV page (the paper's mapping-policy
                          granularity: one page == one unit of DRAM-row
                          placement and of offload traffic).
    ``resident_pages``  — device-resident page budget per KV stream
                          (excl. the 2 reserved pages).  When live slots
                          need more, the engine preempts a victim and
                          offloads its pages to host.
    ``max_ctx``         — logical context capacity per slot; ``None``
                          means the engine's ``max_len``.  May exceed
                          ``max_len``: decode keeps appending pages past
                          the prefill cap, which is how requests outgrow
                          the old contiguous per-slot allocation.
    ``state_pages``     — pool extent per recurrent *state* stream,
                          including the reserved pages (``None`` =
                          ``max_batch + shards * RESERVED_PAGES``, the
                          minimum that can hold every slot).  State
                          pools shard their page dim across the data
                          axes exactly like KV pools, but only when the
                          extent divides the axis — on a mesh, size
                          this like ``resident_pages`` (a per-device
                          share times the device count) or the pool
                          replicates and the per-device state bill
                          grows with the mesh.
    ``shards``          — device-local pool extents to build
                          (:mod:`repro.serve.paging` layout note).
                          The default 1 lets the engine auto-resolve
                          from its mesh's data extent
                          (:meth:`repro.dist.sharding.ShardingPolicy.decode_shards`);
                          set it explicitly to build a mesh-shaped
                          cache geometry on a different (e.g. solo
                          compile-only) mesh, as the partitioning
                          auditor does.
    ``sharing``         — prefix-sharing/copy-on-write knobs
                          (:class:`PrefixSharingConfig`); ``None``
                          (default) disables sharing entirely and
                          serves exactly the pre-sharing allocator.

    Field-local constraints are checked at construction; the
    cross-field budget floor (``resident_pages`` must hold one fully
    decoded slot, which needs the model's layer mix) is checked by
    :meth:`validate`, which the engine calls before lowering anything —
    a bad config fails eagerly with the offending field named instead
    of deep inside :class:`PageTable`.
    """

    page_size: int = 16
    resident_pages: Optional[int] = None
    max_ctx: Optional[int] = None
    state_pages: Optional[int] = None
    shards: int = 1
    sharing: Optional[PrefixSharingConfig] = None

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError(
                f"PagedCacheConfig.shards must be >= 1 (device-local pool "
                f"extents), got {self.shards}")
        if self.resident_pages is not None and self.resident_pages % self.shards:
            raise ValueError(
                f"PagedCacheConfig.resident_pages={self.resident_pages} must "
                f"split evenly across shards={self.shards} device-local "
                f"extents")
        if self.state_pages is not None and self.state_pages % self.shards:
            raise ValueError(
                f"PagedCacheConfig.state_pages={self.state_pages} must split "
                f"evenly across shards={self.shards} device-local extents")
        if self.page_size < 1:
            raise ValueError(
                f"PagedCacheConfig.page_size must be > 0 (tokens per KV "
                f"page), got {self.page_size}")
        if self.resident_pages is not None and self.resident_pages < 1:
            raise ValueError(
                f"PagedCacheConfig.resident_pages must be >= 1 when set "
                f"(device page budget per KV stream), got "
                f"{self.resident_pages}")
        if self.state_pages is not None and self.state_pages < 1:
            raise ValueError(
                f"PagedCacheConfig.state_pages must be >= 1 when set "
                f"(state-stream pool extent incl. reserved pages), got "
                f"{self.state_pages}")
        if self.max_ctx is not None and self.max_ctx < 1:
            raise ValueError(
                f"PagedCacheConfig.max_ctx must be >= 1 when set "
                f"(logical context capacity per slot), got {self.max_ctx}")

    def slot_floor(self, cfg, max_ctx: int) -> int:
        """Pages one fully decoded slot needs in its largest KV stream
        (the guaranteed-progress floor for ``resident_pages``)."""
        return slot_floor(cfg, max_ctx, self.page_size)

    def validate(self, cfg, max_ctx: Optional[int] = None) -> None:
        """Cross-field checks against a model config (and the engine's
        resolved ``max_ctx``, defaulting to this config's own)."""
        ctx = int(max_ctx if max_ctx is not None else (self.max_ctx or 0))
        if ctx < 1:
            raise ValueError(
                "PagedCacheConfig.validate needs a positive max_ctx "
                "(none set on the config and none passed)")
        floor = self.slot_floor(cfg, ctx)
        if (self.resident_pages is not None
                and self.resident_pages // self.shards < floor):
            per = (f" per shard ({self.shards} device-local extents)"
                   if self.shards > 1 else "")
            raise ValueError(
                f"PagedCacheConfig.resident_pages={self.resident_pages} "
                f"cannot hold one fully decoded slot{per}: max_ctx={ctx} at "
                f"page_size={self.page_size} needs {floor} pages in the "
                f"largest KV stream; the engine could deadlock with every "
                f"other slot already offloaded")


class _Stream:
    """Host-side allocator state of one cache stream.

    ``free`` is one free list *per data shard*: ``free[g]`` holds only
    global page ids inside shard ``g``'s pool extent
    ``[g*ext, (g+1)*ext)``, whose first ``RESERVED_PAGES`` ids are that
    shard's private ZERO/DUMP pair (:meth:`zero` / :meth:`dump`).

    Prefix-sharing registry (KV streams only): ``shared[g]`` maps a
    content key (:func:`prefix_page_keys`) to the live page holding
    that content inside shard ``g``'s extent; ``ref[pid]`` counts the
    slots whose block table points at a registered page, and
    ``rkey[pid]`` remembers the (shard, key) entry so forks and
    releases can unregister without a reverse scan."""

    __slots__ = ("where", "kind", "cache_len", "n_lp", "n_pages", "shards",
                 "ext", "free", "slot_pages", "shared", "ref", "rkey")

    def __init__(self, where, kind, cache_len, n_lp, n_pages, shards=1):
        self.where = where            # ("groups", i) | ("tail", i)
        self.kind = kind
        self.cache_len = cache_len    # None for state streams
        self.n_lp = n_lp              # logical pages (1 for state streams)
        self.n_pages = n_pages        # pool extent incl. reserved pages
        self.shards = shards
        assert n_pages % shards == 0, (where, n_pages, shards)
        self.ext = n_pages // shards  # per-shard pool extent
        self.free: List[List[int]] = []
        self.reset_free()
        # KV: {slot: {jdx: pid}}; state: {slot: pid}
        self.slot_pages: Dict[int, object] = {}
        self.shared: List[Dict[bytes, int]] = [{} for _ in range(shards)]
        self.ref: Dict[int, int] = {}
        self.rkey: Dict[int, Tuple[int, bytes]] = {}

    def reset_free(self) -> None:
        self.free = [list(range(g * self.ext + RESERVED_PAGES,
                                (g + 1) * self.ext))
                     for g in range(self.shards)]

    def reset_sharing(self) -> None:
        self.shared = [{} for _ in range(self.shards)]
        self.ref.clear()
        self.rkey.clear()

    def zero(self, g: int) -> int:
        """Global id of shard ``g``'s ZERO page."""
        return g * self.ext + ZERO_PAGE

    def dump(self, g: int) -> int:
        """Global id of shard ``g``'s DUMP page."""
        return g * self.ext + DUMP_PAGE

    @property
    def is_state(self) -> bool:
        return self.cache_len is None


@dataclasses.dataclass
class PagePayload:
    """Host-resident copy of one offloaded slot (all streams).

    ``kv[si] = (jdx->row, k_pages, v_pages)`` with contents shaped
    ``[G?, n_rows, page_size, kv_heads, head_dim]``;
    ``state[si] = (conv, h)``.  ``tokens`` is the slot's context length
    at offload time (for traffic accounting).
    """

    kv: Dict[int, Tuple[Dict[int, int], np.ndarray, np.ndarray]]
    state: Dict[int, Tuple[np.ndarray, np.ndarray]]
    tokens: int

    def pages_needed(self) -> Dict[int, int]:
        return {si: len(jdx_rows) for si, (jdx_rows, _, _) in self.kv.items()}


class PageTable:
    """Page allocator + jitted cache-update ops for one engine.

    All device-side mutation goes through jitted functions whose cache
    output can be pinned to the decode step's shardings
    (``cache_shardings``), so the admit/decode/offload round trip stays
    layout-stable on real meshes.
    """

    def __init__(self, model: TransformerLM, max_batch: int, max_ctx: int,
                 page_size: int, resident_pages: Optional[int] = None,
                 cache_shardings=None, state_pages: Optional[int] = None,
                 shards: int = 1):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.model = model
        self.cfg = model.cfg
        self.max_batch = int(max_batch)
        self.max_ctx = int(max_ctx)
        self.page_size = int(page_size)
        self.shards = int(shards)
        if self.max_batch % self.shards:
            raise ValueError(
                f"max_batch={self.max_batch} slots cannot pin evenly to "
                f"shards={self.shards} device-local pool extents (slots "
                f"ride the data axes in contiguous blocks)")
        self.slots_per_shard = self.max_batch // self.shards
        self._csh = cache_shardings

        self.streams: List[_Stream] = []
        min_budget = slot_floor(self.cfg, self.max_ctx, self.page_size)
        if resident_pages is None:
            # ample default: every slot fully decoded stays resident
            resident_pages = min_budget * self.max_batch
        if resident_pages % self.shards:
            raise ValueError(
                f"resident_pages={resident_pages} must split evenly across "
                f"shards={self.shards} device-local extents")
        if resident_pages // self.shards < min_budget:
            per = (f" in each of the {self.shards} device-local extents"
                   if self.shards > 1 else "")
            raise ValueError(
                f"resident_pages={resident_pages} cannot hold one fully "
                f"decoded slot{per} ({min_budget} pages of {page_size} "
                f"tokens for max_ctx={self.max_ctx}); the engine could "
                f"deadlock with every other slot already offloaded")
        self.resident_pages = int(resident_pages)
        # every shard carries its own reserved ZERO/DUMP pair
        self.n_pages = self.resident_pages + self.shards * RESERVED_PAGES

        state_floor = self.max_batch + self.shards * RESERVED_PAGES
        if state_pages is None:
            state_pages = state_floor
        if state_pages % self.shards:
            raise ValueError(
                f"state_pages={state_pages} must split evenly across "
                f"shards={self.shards} device-local extents")
        if state_pages < state_floor:
            raise ValueError(
                f"state_pages={state_pages} cannot hold every slot's "
                f"recurrent state: max_batch={self.max_batch} slots need "
                f"{state_floor} pages (one each plus {RESERVED_PAGES} "
                f"reserved per shard x {self.shards} shard(s))")
        self.state_pages = int(state_pages)

        for where, kind in self._positions():
            if kind in ("global", "local"):
                L = self.cfg.decode_cache_len(kind, self.max_ctx)
                self.streams.append(_Stream(
                    where, kind, L, n_logical_pages(L, page_size),
                    self.n_pages, self.shards))
            else:
                self.streams.append(_Stream(
                    where, kind, None, 1, self.state_pages, self.shards))

        # per-serve prefix-sharing counters (reset() zeroes them); tests
        # pin the allocation-once bound through these
        self.stats: Dict[str, int] = {
            "pages_registered": 0, "pages_attached": 0,
            "cow_forks": 0, "full_attaches": 0}
        # per-stream layer-token accounting of the most recent admit /
        # admit_cached / attach_prefix — the engine turns this into the
        # telemetry prefix-hit traffic class
        self.last_admit: Optional[Dict[str, int]] = None

        self.bind_shardings(cache_shardings)

    def shard_of(self, slot: int) -> int:
        """Data shard (pool extent) batch slot ``slot`` is pinned to."""
        return int(slot) // self.slots_per_shard

    def bind_shardings(self, cache_shardings=None) -> None:
        """(Re)build the jitted cache ops, pinning their cache output to
        ``cache_shardings`` (the decode step's) so the admit/decode/
        offload round trip is layout-stable on real meshes.  The engine
        calls this once the decode step — and therefore the cache
        placement — exists."""
        self._csh = cache_shardings
        # donate the cache arg (as the decode step does): these ops
        # rewrite a slice of the pools, and without donation each admit/
        # retire/page-assign would copy every pool buffer on device.
        # fetch must NOT donate — offload reads pages out of a cache
        # that stays live.
        kw = {"donate_argnums": (0,)}
        if cache_shardings is not None:
            kw["out_shardings"] = cache_shardings
        self._insert_jit = jax.jit(self._insert_fn, **kw)
        self._release_jit = jax.jit(self._release_fn, **kw)
        self._restore_jit = jax.jit(self._restore_fn, **kw)
        self._attach_jit = jax.jit(self._attach_fn, **kw)
        self._assign_jit = {
            si: jax.jit(lambda c, s, j, p, _si=si: self._assign_fn(_si, c, s, j, p),
                        **kw)
            for si, st in enumerate(self.streams) if not st.is_state}
        self._fork_jit = {
            si: jax.jit(lambda c, s, src, dst, j, _si=si:
                        self._fork_fn(_si, c, s, src, dst, j), **kw)
            for si, st in enumerate(self.streams) if not st.is_state}
        self._fetch_jit = {
            si: (jax.jit(lambda c, pid, _si=si: self._fetch_state_fn(_si, c, pid))
                 if st.is_state else
                 jax.jit(lambda c, ids, _si=si: self._fetch_kv_fn(_si, c, ids)))
            for si, st in enumerate(self.streams)}

    def reset(self) -> None:
        """Drop all allocations (fresh serve call: every page free,
        every sharing registry empty, stats zeroed)."""
        for st in self.streams:
            st.reset_free()
            st.slot_pages.clear()
            st.reset_sharing()
        for k in self.stats:
            self.stats[k] = 0
        self.last_admit = None

    # ------------------------------------------------------------- structure
    def _positions(self):
        for i, kind in enumerate(self.cfg.attn_pattern):
            yield ("groups", i), kind
        for i, kind in enumerate(self.cfg.pattern_tail):
            yield ("tail", i), kind

    def _get(self, cache, where):
        return cache[where[0]][where[1]]

    @staticmethod
    def _replace(cache, where, node):
        top, i = where
        seq = list(cache[top])
        seq[i] = node
        return {**cache, top: tuple(seq)}

    def init_cache(self):
        return self.model.init_paged_cache(
            self.max_batch, self.max_ctx, self.page_size, self.n_pages,
            state_pages=self.state_pages, shards=self.shards)

    # -------------------------------------------------------------- sizing
    def kv_pages_for(self, tokens: int, stream: _Stream) -> int:
        """Pages prefilling ``tokens`` prompt rows writes in a stream
        (a prompt past the ring length wraps and touches every page)."""
        return n_logical_pages(
            min(max(int(tokens), 1), stream.cache_len), self.page_size)

    # --------------------------------------------------- prefix sharing
    @staticmethod
    def _shareable(st: _Stream, plen: int) -> bool:
        """A stream's prefill pages are content-addressable only when
        the prompt fits its ring (``plen <= cache_len``): a wrapped
        prefill overwrites page rows, so page content stops being a
        pure function of the token prefix.  State streams never share
        (rewritten every decode step)."""
        return (not st.is_state) and plen <= st.cache_len

    def _stream_layers(self, st: _Stream) -> int:
        """Model layers stacked behind one page id of this stream —
        the layer-token multiplier for hit/fork traffic accounting."""
        return self.cfg.n_groups if st.where[0] == "groups" else 1

    def _page_key(self, keys: PrefixKeys, j: int, plen: int):
        """Content key of prompt page ``j`` (full-page chain digest, or
        the tail digest for the partial last page)."""
        return (keys.full[j] if (j + 1) * self.page_size <= plen
                else keys.tail)

    def _register(self, st: _Stream, g: int, key: bytes, pid: int) -> None:
        st.shared[g][key] = pid
        st.ref[pid] = 1
        st.rkey[pid] = (g, key)
        self.stats["pages_registered"] += 1

    def _unregister(self, st: _Stream, pid: int) -> None:
        g, key = st.rkey.pop(pid)
        del st.ref[pid]
        if st.shared[g].get(key) == pid:
            del st.shared[g][key]

    def _decref(self, st: _Stream, g: int, pid: int) -> None:
        """Drop one block-table reference to a registered page; the
        page frees (and leaves the registry) when nobody points at it."""
        st.ref[pid] -= 1
        if st.ref[pid] == 0:
            self._unregister(st, pid)
            st.free[g].append(pid)

    def fully_shareable(self, plen: int) -> bool:
        """Whether every KV stream can content-address a ``plen``-token
        prompt (no ring wrap anywhere) — the engine's condition for
        whole-prompt memoization: only then do the registered pages plus
        a state snapshot reconstruct the complete admission."""
        return all(self._shareable(st, plen) for st in self.streams
                   if not st.is_state)

    def _pages_missing(self, st: _Stream, g: int, plen: int,
                       keys: Optional[PrefixKeys]) -> int:
        """Fresh pages admitting a ``plen`` prompt would pop from shard
        ``g``'s free list in this stream (registry hits cost none)."""
        need = self.kv_pages_for(plen, st)
        if keys is None or not self._shareable(st, plen):
            return need
        return sum(1 for j in range(need)
                   if st.shared[g].get(self._page_key(keys, j, plen)) is None)

    def can_admit(self, plen: int, slot: int,
                  keys: Optional[PrefixKeys] = None) -> bool:
        """Whether ``slot``'s shard has pages for a ``plen``-token
        prompt in every stream (allocation is strictly shard-local).
        With ``keys``, registry hits are free — only the miss pages
        need free-list capacity."""
        g = self.shard_of(slot)
        for st in self.streams:
            need = 1 if st.is_state else self._pages_missing(st, g, plen, keys)
            if len(st.free[g]) < need:
                return False
        return True

    def can_admit_cached(self, slot: int, plen: int,
                         keys: Optional[PrefixKeys]) -> bool:
        """Whether the whole prompt is resident in ``slot``'s shard:
        every KV page of every stream is registered (full skip needs no
        prefill compute at all) and each state stream has a free page
        for the host-snapshot restore."""
        if keys is None:
            return False
        g = self.shard_of(slot)
        for st in self.streams:
            if st.is_state:
                if not st.free[g]:
                    return False
                continue
            if not self._shareable(st, plen):
                return False
            for j in range(self.kv_pages_for(plen, st)):
                if st.shared[g].get(self._page_key(keys, j, plen)) is None:
                    return False
        return True

    def free_page_counts(self) -> Dict[Tuple[str, int], int]:
        return {st.where: sum(len(f) for f in st.free)
                for st in self.streams}

    # --------------------------------------------------- placement geometry
    _ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}

    def stream_name(self, si: int) -> str:
        st = self.streams[si]
        return f"{'state' if st.is_state else 'kv'}:{st.where[0]}{st.where[1]}"

    def stream_names(self) -> Tuple[str, ...]:
        """Stable stream labels, in stream-list order — the binding
        contract between a :class:`repro.core.trace.PageAccessTrace`
        and the :class:`repro.core.placement.StreamGeometry` set."""
        return tuple(self.stream_name(si) for si in range(len(self.streams)))

    def stream_geometries(self, cfg=None):
        """Per-stream :class:`repro.core.placement.StreamGeometry` —
        the DRAM shape of this table's pools.

        A ``("groups", i)`` stream's page id indexes ``n_groups``
        stacked per-layer pool pages at once (``init_paged_cache``
        broadcasts the group's layers over one leading axis), so its
        placement page carries the group's whole stack of bytes.

        ``cfg`` overrides the model config for sizing (e.g. the full
        arch while the engine serves the smoke twin); it must share the
        smoke config's attn_pattern/pattern_tail structure or the
        stream list would not line up.
        """
        from repro.core.placement import StreamGeometry

        mcfg = self.cfg if cfg is None else cfg
        if cfg is not None and (
                tuple(mcfg.attn_pattern) != tuple(self.cfg.attn_pattern)
                or tuple(mcfg.pattern_tail) != tuple(self.cfg.pattern_tail)):
            raise ValueError(
                f"stream_geometries: override config {mcfg.name!r} has "
                f"pattern {mcfg.attn_pattern}/{mcfg.pattern_tail} but the "
                f"table was built for {self.cfg.attn_pattern}/"
                f"{self.cfg.pattern_tail}")
        isz = self._ITEMSIZE[mcfg.dtype]
        geoms = []
        for si, st in enumerate(self.streams):
            if st.is_state:
                if st.kind == "ssm":
                    pb = ((mcfg.ssm_conv - 1) * mcfg.d_inner * isz
                          + mcfg.d_inner * mcfg.ssm_state * 4)
                else:   # rglru: f32 hidden state rides beside the conv tap
                    pb = ((mcfg.conv1d_width - 1) * mcfg.resolved_lru_width
                          * isz + mcfg.resolved_lru_width * 4)
            else:
                pb = (2 * self.page_size * mcfg.n_kv_heads
                      * mcfg.resolved_head_dim * isz)
            if st.where[0] == "groups":
                pb *= mcfg.n_groups
            geoms.append(StreamGeometry(
                name=self.stream_name(si), n_pages=st.n_pages,
                page_bytes=int(pb), shards=st.shards,
                reserved_per_shard=RESERVED_PAGES))
        return tuple(geoms)

    def slot_page_ids(self, slot: int) -> List[Tuple[int, Tuple[int, ...]]]:
        """Physical pages ``slot`` holds right now, per stream — the
        page set one decode step reads AND writes (allocate-on-write:
        a resident page exists only because the slot's context reaches
        into it, and the KV gather sweeps every resident page)."""
        out = []
        for si, st in enumerate(self.streams):
            held = st.slot_pages.get(slot)
            if held is None:
                continue
            pids = (held,) if st.is_state else tuple(held.values())
            if pids:
                out.append((si, pids))
        return out

    # ------------------------------------------------------------ jitted ops
    def _insert_fn(self, cache, one, slot, pages, blocks, zeros, dumps):
        """Scatter a prefilled batch-1 contiguous cache into this
        slot's freshly assigned pages.  ``pages`` mirrors the stream
        list: KV entries are ``[n_lp]`` int32 *write* page ids (-1 =
        this logical page gets no fresh write -> the scatter row is
        redirected to the slot's shard's DUMP), state entries are
        scalar int32 page ids.  ``blocks`` carries the block-table row
        per KV stream (-1 -> ZERO); it differs from ``pages`` exactly
        on prefix-sharing attach rows, whose block points at the shared
        page while the redundant prefill write lands in DUMP.  Without
        sharing ``blocks is pages`` and this is the original admit,
        bit for bit.  ``zeros`` / ``dumps`` are the per-stream
        reserved-page ids of the slot's shard, passed traced so one
        compile serves every slot."""
        for si, st in enumerate(self.streams):
            pc, oc = self._get(cache, st.where), self._get(one, st.where)
            grouped = st.where[0] == "groups"
            if st.is_state:
                pc = self._ins_state(pc, oc, slot, pages[si], grouped)
            else:
                pc = self._ins_kv(pc, oc, slot, pages[si], blocks[si],
                                  grouped, zeros[si], dumps[si])
            cache = self._replace(cache, st.where, pc)
        return cache

    def _ins_kv(self, pc: PagedKVCache, oc: KVCache, slot, pids, bids,
                grouped, zero, dump):
        P, L = pc.page_size, pc.cache_len
        n_lp = pids.shape[0]
        write_ids = jnp.where(pids < 0, dump, pids)
        pad = n_lp * P - L

        def scat(pool, rows):            # rows: [L, kvh, hd]
            src = jnp.pad(rows, ((0, pad), (0, 0), (0, 0)))
            return pool.at[write_ids].set(
                src.reshape((n_lp, P) + rows.shape[1:]))

        block_row = jnp.where(bids < 0, zero, bids)
        if grouped:
            kp = jax.vmap(scat)(pc.kp, oc.k[:, 0])
            vp = jax.vmap(scat)(pc.vp, oc.v[:, 0])
            block = pc.block.at[:, slot].set(block_row)
        else:
            kp = scat(pc.kp, oc.k[0])
            vp = scat(pc.vp, oc.v[0])
            block = pc.block.at[slot].set(block_row)
        return dataclasses.replace(
            pc, kp=kp, vp=vp, block=block,
            length=jnp.maximum(pc.length, oc.length))

    def _ins_state(self, pc, oc, slot, pid, grouped):
        if grouped:
            return dataclasses.replace(
                pc,
                conv_p=pc.conv_p.at[:, pid].set(oc.conv[:, 0]),
                h_p=pc.h_p.at[:, pid].set(oc.h[:, 0]),
                block=pc.block.at[:, slot].set(pid))
        return dataclasses.replace(
            pc,
            conv_p=pc.conv_p.at[pid].set(oc.conv[0]),
            h_p=pc.h_p.at[pid].set(oc.h[0]),
            block=pc.block.at[slot].set(pid))

    def _release_fn(self, cache, slot, dumps):
        """Point every block-table row of ``slot`` back at its shard's
        DUMP page (``dumps``: per-stream traced ids)."""
        for si, st in enumerate(self.streams):
            pc = self._get(cache, st.where)
            grouped = st.where[0] == "groups"
            if grouped:
                block = pc.block.at[:, slot].set(dumps[si])
            else:
                block = pc.block.at[slot].set(dumps[si])
            cache = self._replace(cache, st.where,
                                  dataclasses.replace(pc, block=block))
        return cache

    def _assign_fn(self, si, cache, slot, jdx, pid):
        """Assign a zeroed page to logical page ``jdx`` of ``slot``
        (decode growth: allocate-on-write at a page boundary)."""
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            pc = dataclasses.replace(
                pc,
                kp=pc.kp.at[:, pid].set(0),
                vp=pc.vp.at[:, pid].set(0),
                block=pc.block.at[:, slot, jdx].set(pid))
        else:
            pc = dataclasses.replace(
                pc,
                kp=pc.kp.at[pid].set(0),
                vp=pc.vp.at[pid].set(0),
                block=pc.block.at[slot, jdx].set(pid))
        return self._replace(cache, st.where, pc)

    def _fork_fn(self, si, cache, slot, src, dst, jdx):
        """Copy-on-write fork: duplicate shared page ``src`` into the
        freshly allocated ``dst`` and re-target this slot's block row —
        the only device traffic sharing adds (one page read + write per
        fork, which telemetry bills as the ``cow`` class)."""
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            pc = dataclasses.replace(
                pc,
                kp=pc.kp.at[:, dst].set(pc.kp[:, src]),
                vp=pc.vp.at[:, dst].set(pc.vp[:, src]),
                block=pc.block.at[:, slot, jdx].set(dst))
        else:
            pc = dataclasses.replace(
                pc,
                kp=pc.kp.at[dst].set(pc.kp[src]),
                vp=pc.vp.at[dst].set(pc.vp[src]),
                block=pc.block.at[slot, jdx].set(dst))
        return self._replace(cache, st.where, pc)

    def _attach_fn(self, cache, slot, args, zeros):
        """Admit a slot from already-resident content: KV entries of
        ``args`` are ``(block_row [n_lp] with -1 -> ZERO, length)`` —
        only the block table and the batch length high-water mark move,
        no page content is written; state entries are ``(pid, conv,
        h)`` restored from a host snapshot exactly like
        :meth:`restore` (state pages are never shared)."""
        for si, st in enumerate(self.streams):
            pc = self._get(cache, st.where)
            grouped = st.where[0] == "groups"
            if st.is_state:
                pid, conv, h = args[si]
                if grouped:
                    pc = dataclasses.replace(
                        pc,
                        conv_p=pc.conv_p.at[:, pid].set(conv),
                        h_p=pc.h_p.at[:, pid].set(h),
                        block=pc.block.at[:, slot].set(pid))
                else:
                    pc = dataclasses.replace(
                        pc,
                        conv_p=pc.conv_p.at[pid].set(conv),
                        h_p=pc.h_p.at[pid].set(h),
                        block=pc.block.at[slot].set(pid))
            else:
                bids, length = args[si]
                block_row = jnp.where(bids < 0, zeros[si], bids)
                if grouped:
                    block = pc.block.at[:, slot].set(block_row)
                else:
                    block = pc.block.at[slot].set(block_row)
                pc = dataclasses.replace(
                    pc, block=block,
                    length=jnp.maximum(pc.length, length))
            cache = self._replace(cache, st.where, pc)
        return cache

    def _fetch_kv_fn(self, si, cache, ids):
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            return pc.kp[:, ids], pc.vp[:, ids]
        return pc.kp[ids], pc.vp[ids]

    def _fetch_state_fn(self, si, cache, pid):
        st = self.streams[si]
        pc = self._get(cache, st.where)
        if st.where[0] == "groups":
            return pc.conv_p[:, pid], pc.h_p[:, pid]
        return pc.conv_p[pid], pc.h_p[pid]

    def _restore_fn(self, cache, slot, payload):
        """Write offloaded page contents into freshly assigned pages.
        ``payload`` mirrors the stream list: KV entries are
        ``(pids [n_rows], jdxs [n_rows], k_pages, v_pages)`` (pids
        already allocated), state entries ``(pid, conv, h)``."""
        for si, st in enumerate(self.streams):
            pc = self._get(cache, st.where)
            grouped = st.where[0] == "groups"
            if st.is_state:
                pid, conv, h = payload[si]
                if grouped:
                    pc = dataclasses.replace(
                        pc,
                        conv_p=pc.conv_p.at[:, pid].set(conv),
                        h_p=pc.h_p.at[:, pid].set(h),
                        block=pc.block.at[:, slot].set(pid))
                else:
                    pc = dataclasses.replace(
                        pc,
                        conv_p=pc.conv_p.at[pid].set(conv),
                        h_p=pc.h_p.at[pid].set(h),
                        block=pc.block.at[slot].set(pid))
            else:
                pids, jdxs, kpg, vpg = payload[si]
                if grouped:
                    pc = dataclasses.replace(
                        pc,
                        kp=pc.kp.at[:, pids].set(kpg),
                        vp=pc.vp.at[:, pids].set(vpg),
                        block=pc.block.at[:, slot, jdxs].set(pids))
                else:
                    pc = dataclasses.replace(
                        pc,
                        kp=pc.kp.at[pids].set(kpg),
                        vp=pc.vp.at[pids].set(vpg),
                        block=pc.block.at[slot, jdxs].set(pids))
            cache = self._replace(cache, st.where, pc)
        return cache

    # ----------------------------------------------------------- operations
    def _reserved_ids(self, slot: int):
        """Per-stream (zeros, dumps) traced scalars of ``slot``'s shard,
        for the jitted ops that re-target dead block rows."""
        g = self.shard_of(slot)
        zeros = tuple(jnp.asarray(st.zero(g), jnp.int32)
                      for st in self.streams)
        dumps = tuple(jnp.asarray(st.dump(g), jnp.int32)
                      for st in self.streams)
        return zeros, dumps

    def admit(self, cache, one, slot: int, plen: int,
              keys: Optional[PrefixKeys] = None):
        """Allocate pages (from ``slot``'s shard extent) for a freshly
        prefilled request and scatter its contiguous batch-1 cache into
        them.

        With ``keys`` (prefix sharing), each prompt page first probes
        the shard's content registry: a hit attaches the live shared
        page (block row points at it, refcount +1, the redundant
        prefill write for that row lands in DUMP); a miss allocates as
        before and registers the fresh page under its content key.
        ``keys=None`` is the original allocator, bit for bit."""
        g = self.shard_of(slot)
        pages, blocks = [], []
        adm = {"attached_pages": 0, "registered_pages": 0,
               "attached_layer_tokens": 0, "total_layer_tokens": 0}
        for st in self.streams:
            if st.is_state:
                pid = st.free[g].pop()
                st.slot_pages[slot] = pid
                pages.append(jnp.asarray(pid, jnp.int32))
                blocks.append(pages[-1])
                continue
            need = self.kv_pages_for(plen, st)
            layers = self._stream_layers(st)
            ok_share = keys is not None and self._shareable(st, plen)
            held: Dict[int, int] = {}
            vec = np.full((st.n_lp,), -1, np.int32)   # write ids
            bvec = np.full((st.n_lp,), -1, np.int32)  # block rows
            for j in range(need):
                ptoks = (min(plen, (j + 1) * self.page_size)
                         - j * self.page_size)
                adm["total_layer_tokens"] += ptoks * layers
                key = self._page_key(keys, j, plen) if ok_share else None
                hit = st.shared[g].get(key) if key is not None else None
                if hit is not None:
                    st.ref[hit] += 1
                    held[j] = hit
                    bvec[j] = hit
                    adm["attached_pages"] += 1
                    adm["attached_layer_tokens"] += ptoks * layers
                    self.stats["pages_attached"] += 1
                else:
                    pid = st.free[g].pop()
                    held[j] = pid
                    vec[j] = pid
                    bvec[j] = pid
                    if key is not None:
                        self._register(st, g, key, pid)
                        adm["registered_pages"] += 1
            st.slot_pages[slot] = held
            pages.append(jnp.asarray(vec))
            blocks.append(jnp.asarray(bvec))
        self.last_admit = adm
        zeros, dumps = self._reserved_ids(slot)
        return self._insert_jit(cache, one, jnp.asarray(slot, jnp.int32),
                                tuple(pages), tuple(blocks), zeros, dumps)

    def state_snapshot(self, one) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        """Host copy of the batch-1 prefill cache's recurrent state,
        keyed by stream index — what :meth:`admit_cached` writes back
        (state pages are never shared, so the full-prompt memo restores
        them through the same host round trip offload/restore uses)."""
        host = jax.devices("cpu")[0]
        snap = {}
        for si, st in enumerate(self.streams):
            if not st.is_state:
                continue
            oc = self._get(one, st.where)
            grouped = st.where[0] == "groups"
            conv = oc.conv[:, 0] if grouped else oc.conv[0]
            h = oc.h[:, 0] if grouped else oc.h[0]
            snap[si] = (np.asarray(jax.device_put(conv, host)),
                        np.asarray(jax.device_put(h, host)))
        return snap

    def admit_cached(self, cache, slot: int, plen: int, keys: PrefixKeys,
                     state_payload: Dict[int, Tuple[np.ndarray, np.ndarray]]):
        """Admit a whole prompt from resident shared pages — the
        full-skip path: every KV page of every stream attaches from the
        registry (no prefill ran; :meth:`can_admit_cached` must hold),
        recurrent state restores from ``state_payload`` (a
        :meth:`state_snapshot` taken when the prompt first prefilled).
        The KV length high-water mark is ``min(plen, cache_len)`` per
        stream, exactly what the skipped prefill's admit would have
        set."""
        g = self.shard_of(slot)
        args = []
        adm = {"attached_pages": 0, "registered_pages": 0,
               "attached_layer_tokens": 0, "total_layer_tokens": 0}
        for si, st in enumerate(self.streams):
            if st.is_state:
                if si not in state_payload:
                    raise PageTableError(
                        f"admit_cached: no state snapshot for stream "
                        f"{st.where} (kind={st.kind!r}) — the memo entry "
                        f"must carry every recurrent stream")
                pid = st.free[g].pop()
                st.slot_pages[slot] = pid
                conv, h = state_payload[si]
                args.append((jnp.asarray(pid, jnp.int32),
                             jnp.asarray(conv), jnp.asarray(h)))
                continue
            need = self.kv_pages_for(plen, st)
            layers = self._stream_layers(st)
            held: Dict[int, int] = {}
            bvec = np.full((st.n_lp,), -1, np.int32)
            for j in range(need):
                pid = st.shared[g][self._page_key(keys, j, plen)]
                st.ref[pid] += 1
                held[j] = pid
                bvec[j] = pid
                ptoks = (min(plen, (j + 1) * self.page_size)
                         - j * self.page_size)
                adm["attached_pages"] += 1
                adm["attached_layer_tokens"] += ptoks * layers
                adm["total_layer_tokens"] += ptoks * layers
                self.stats["pages_attached"] += 1
            st.slot_pages[slot] = held
            args.append((jnp.asarray(bvec),
                         jnp.asarray(min(plen, st.cache_len), jnp.int32)))
        self.stats["full_attaches"] += 1
        self.last_admit = adm
        zeros, _ = self._reserved_ids(slot)
        return self._attach_jit(cache, jnp.asarray(slot, jnp.int32),
                                tuple(args), zeros)

    def joint_prefix_pages(self, slot: int, keys: Optional[PrefixKeys],
                           plen: int) -> int:
        """Longest run of *full* prompt pages resident in ``slot``'s
        shard across **every** KV stream (the suffix-feed attach
        depth), capped so at least one prompt token remains to feed.
        Returns 0 for recurrent models (state is not addressable by
        token prefix) or when any stream cannot share."""
        if keys is None:
            return 0
        g = self.shard_of(slot)
        k = min((plen - 1) // self.page_size, len(keys.full))
        for st in self.streams:
            if st.is_state or not self._shareable(st, plen):
                return 0
            run = 0
            for j in range(k):
                if st.shared[g].get(keys.full[j]) is None:
                    break
                run += 1
            k = min(k, run)
            if k == 0:
                return 0
        return k

    def attach_prefix(self, cache, slot: int, keys: PrefixKeys, k: int):
        """Suffix-feed admission: attach the first ``k`` full prompt
        pages of every KV stream from the registry and nothing else —
        the engine teacher-forces the remaining prompt tokens through
        the decode step, which allocates its own write pages via
        :meth:`prepare_step`."""
        g = self.shard_of(slot)
        args = []
        adm = {"attached_pages": 0, "registered_pages": 0,
               "attached_layer_tokens": 0, "total_layer_tokens": 0}
        for si, st in enumerate(self.streams):
            if st.is_state:
                raise PageTableError(
                    f"attach_prefix: stream {st.where} (kind={st.kind!r}) "
                    f"is recurrent state; suffix-feed sharing is "
                    f"attention-only")
            layers = self._stream_layers(st)
            held: Dict[int, int] = {}
            bvec = np.full((st.n_lp,), -1, np.int32)
            for j in range(k):
                pid = st.shared[g][keys.full[j]]
                st.ref[pid] += 1
                held[j] = pid
                bvec[j] = pid
                adm["attached_pages"] += 1
                adm["attached_layer_tokens"] += self.page_size * layers
                adm["total_layer_tokens"] += self.page_size * layers
                self.stats["pages_attached"] += 1
            st.slot_pages[slot] = held
            args.append((jnp.asarray(bvec),
                         jnp.asarray(min(k * self.page_size, st.cache_len),
                                     jnp.int32)))
        self.last_admit = adm
        zeros, _ = self._reserved_ids(slot)
        return self._attach_jit(cache, jnp.asarray(slot, jnp.int32),
                                tuple(args), zeros)

    def release(self, cache, slot: int):
        """Free a retired slot's pages; its block rows return to DUMP.
        A shared (registered) page only drops one reference — it frees
        when its last holder lets go."""
        g = self.shard_of(slot)
        for st in self.streams:
            held = st.slot_pages.pop(slot, None)
            if held is None:
                continue
            for pid in ([held] if st.is_state else held.values()):
                if pid in st.ref:
                    self._decref(st, g, pid)
                else:
                    st.free[g].append(pid)
        _, dumps = self._reserved_ids(slot)
        return self._release_jit(cache, jnp.asarray(slot, jnp.int32), dumps)

    def prepare_step(self, cache, slot: int, pos: int,
                     cow_events: Optional[List[Tuple[int, int]]] = None):
        """Ensure the page each KV stream will write at ``pos`` is
        assigned (from ``slot``'s shard extent) **and private** to this
        slot.  Returns ``(cache, ok)``; ``ok`` is False when a pool is
        exhausted (the engine must preempt a victim and retry).

        Copy-on-write: when the write lands in a *shared* page
        (refcount > 1) the slot forks — a fresh page is allocated, the
        shared content copied device-side, and the block row
        re-targeted; when the slot is the page's *sole* holder
        (refcount == 1) it simply unregisters the page in place and
        writes through, making every append/ring-wrap bit-identical to
        unshared serving.  Each fork appends ``(stream_index,
        layer_tokens_copied)`` to ``cow_events`` for telemetry.

        Invariant — *partial progress is committed*: page assignments
        (and forks) for streams visited before the exhausted one stay
        in the cache and in ``slot_pages`` even on the ``ok=False``
        return.  That is deliberate and safe: an assigned page is
        recorded under its ``jdx``, so the post-preemption retry skips
        it (a forked page is private, so the retry's ``ref`` probe
        skips it too) and only the still-missing streams act, and page
        content stays consistent until the decode step writes through
        the block table — generations are bit-identical to a serve
        that never exhausted the pool (``tests/test_paged_cache.py``
        pins this).  Callers must not assume the cache is untouched
        when ``ok`` is False."""
        g = self.shard_of(slot)
        for si, st in enumerate(self.streams):
            if st.is_state:
                continue
            jdx = (pos % st.cache_len) // self.page_size
            held = st.slot_pages[slot]
            pid = held.get(jdx)
            if pid is not None:
                if pid not in st.ref:
                    continue              # private page: write through
                if st.ref[pid] == 1:
                    self._unregister(st, pid)   # sole holder: take it
                    continue                    # private in place
                if not st.free[g]:
                    return cache, False
                dst = st.free[g].pop()
                st.ref[pid] -= 1
                held[jdx] = dst
                cache = self._fork_jit[si](
                    cache, jnp.asarray(slot, jnp.int32),
                    jnp.asarray(pid, jnp.int32), jnp.asarray(dst, jnp.int32),
                    jnp.asarray(jdx, jnp.int32))
                self.stats["cow_forks"] += 1
                if cow_events is not None:
                    cow_events.append(
                        (si, self.page_size * self._stream_layers(st)))
                continue
            if not st.free[g]:
                return cache, False
            pid = st.free[g].pop()
            held[jdx] = pid
            cache = self._assign_jit[si](
                cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(jdx, jnp.int32), jnp.asarray(pid, jnp.int32))
        return cache, True

    def offload(self, cache, slot: int, tokens: int):
        """Copy a slot's resident pages to host, free them on device.

        Returns ``(cache, payload)``.  The host copy is explicit
        (``jax.device_put`` onto the CPU backend), so the content
        round-trips through host memory, not a device alias.
        """
        host = jax.devices("cpu")[0]
        g = self.shard_of(slot)
        kv, state = {}, {}
        for si, st in enumerate(self.streams):
            if slot not in st.slot_pages:
                raise PageTableError(
                    f"offload: slot {slot} holds no pages in stream "
                    f"{st.where} (kind={st.kind!r}); live slots there: "
                    f"{sorted(st.slot_pages)} — offload victims must be "
                    f"admitted slots")
            held = st.slot_pages.pop(slot)
            if st.is_state:
                conv, h = self._fetch_jit[si](cache, jnp.asarray(held, jnp.int32))
                state[si] = (np.asarray(jax.device_put(conv, host)),
                             np.asarray(jax.device_put(h, host)))
                st.free[g].append(held)
            else:
                jdxs = sorted(held)
                ids = jnp.asarray([held[j] for j in jdxs], jnp.int32)
                kpg, vpg = self._fetch_jit[si](cache, ids)
                kv[si] = (dict(zip(jdxs, range(len(jdxs)))),
                          np.asarray(jax.device_put(kpg, host)),
                          np.asarray(jax.device_put(vpg, host)))
                # the host payload owns a private copy of shared pages,
                # so offload just drops this slot's references; restore
                # later allocates fresh private pages
                for pid in held.values():
                    if pid in st.ref:
                        self._decref(st, g, pid)
                    else:
                        st.free[g].append(pid)
        _, dumps = self._reserved_ids(slot)
        cache = self._release_jit(cache, jnp.asarray(slot, jnp.int32), dumps)
        return cache, PagePayload(kv=kv, state=state, tokens=int(tokens))

    def can_restore(self, payload: PagePayload, slot: int) -> bool:
        """Whether ``slot``'s shard has pages for the payload in every
        stream (restore allocates strictly shard-locally, like admit)."""
        g = self.shard_of(slot)
        need = payload.pages_needed()
        for si, st in enumerate(self.streams):
            if len(st.free[g]) < (1 if st.is_state else need[si]):
                return False
        return True

    def restore(self, cache, slot: int, payload: PagePayload):
        """Re-admit an offloaded slot: new pages (from ``slot``'s shard
        extent — any slot/shard, not necessarily the original), same
        bytes."""
        g = self.shard_of(slot)
        args = []
        for si, st in enumerate(self.streams):
            if st.is_state:
                pid = st.free[g].pop()
                st.slot_pages[slot] = pid
                conv, h = payload.state[si]
                args.append((jnp.asarray(pid, jnp.int32),
                             jnp.asarray(conv), jnp.asarray(h)))
            else:
                jdx_rows, kpg, vpg = payload.kv[si]
                jdxs = list(jdx_rows)
                pids = [st.free[g].pop() for _ in range(len(jdxs))]
                st.slot_pages[slot] = dict(zip(jdxs, pids))
                args.append((jnp.asarray(pids, jnp.int32),
                             jnp.asarray(jdxs, jnp.int32),
                             jnp.asarray(kpg), jnp.asarray(vpg)))
        return self._restore_jit(cache, jnp.asarray(slot, jnp.int32),
                                 tuple(args))


# ---------------------------------------------------------------------------
# Test/debug helper
# ---------------------------------------------------------------------------
def logical_view(cache):
    """Resolve a paged cache pytree into the contiguous cache pytree a
    ``model.init_cache`` decode would carry (KVCache/SSMCache/RGLRUCache
    with the same ``{'groups', 'tail'}`` structure).

    The paged==contiguous equivalence suite compares this view bitwise
    against the contiguous engine's cache: values must land in the same
    slot order for attention to be bit-identical.
    """
    def one(node):
        if isinstance(node, PagedKVCache):
            if node.block.ndim == 3:      # grouped: [G, ...] leaves
                k, v = jax.vmap(
                    lambda kp, vp, blk: paged_kv_view(
                        dataclasses.replace(node, kp=kp, vp=vp, block=blk))
                )(node.kp, node.vp, node.block)
            else:
                k, v = paged_kv_view(node)
            return KVCache(k=k, v=v, length=node.length)
        if isinstance(node, PagedSSMCache):
            if node.block.ndim == 2:
                return SSMCache(
                    conv=jax.vmap(lambda c, b: c[b])(node.conv_p, node.block),
                    h=jax.vmap(lambda h, b: h[b])(node.h_p, node.block))
            return SSMCache(conv=node.conv_p[node.block],
                            h=node.h_p[node.block])
        if isinstance(node, PagedRGLRUCache):
            if node.block.ndim == 2:
                return RGLRUCache(
                    conv=jax.vmap(lambda c, b: c[b])(node.conv_p, node.block),
                    h=jax.vmap(lambda h, b: h[b])(node.h_p, node.block))
            return RGLRUCache(conv=node.conv_p[node.block],
                              h=node.h_p[node.block])
        return node

    return {
        top: tuple(one(node) for node in cache[top])
        for top in ("groups", "tail")
    }
