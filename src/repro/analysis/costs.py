"""Per-kernel HBM cost handlers for ``pallas_call`` equations.

A Pallas call is opaque to the jaxpr walker: its grid spec decides what
actually crosses HBM (scalar-prefetch operands are fetched once, block
operands are re-DMA'd every time their index map changes), so each
kernel registers a *cost handler* that derives the per-operand byte
movement from the equation's operand avals.

Protocol: ``handler(eqn) -> KernelCost`` where ``reads[i]`` is the HBM
bytes the kernel streams from operand ``i`` over the whole grid and
``writes[j]`` the bytes written to output ``j``.  The traffic pass then
*classifies* those bytes by the taint of each operand (a pool operand's
reads become ``kv_page_read``; an untainted activation operand is a
small on-chip intermediate and is not DRAM traffic) — the handler only
knows geometry, never what the buffers mean.

Handlers are keyed by a source-path fragment matched against the
equation's ``name_and_src_info`` (every kernel body here is a module-
private ``_kernel``, so the *file* is the stable identity).  This
module is import-leaf on purpose: each ``repro.kernels.*.ops`` imports
it to register at import time, and the traffic pass imports those ops
modules to trigger registration — a kernel whose ops module forgets to
register shows up as a ``missing-cost-handler`` finding, which is what
ties cost handlers to their kernels in CI.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

__all__ = ["KernelCost", "register_pallas_cost", "lookup_pallas_cost",
           "registered_pallas_costs"]


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """HBM bytes one ``pallas_call`` moves, per operand / per output."""

    reads: Tuple[int, ...]    # aligned with eqn.invars
    writes: Tuple[int, ...]   # aligned with eqn.outvars


_HANDLERS: Dict[str, Callable] = {}


def register_pallas_cost(path_fragment: str, handler: Callable) -> None:
    """Register ``handler`` for pallas calls whose ``name_and_src_info``
    contains ``path_fragment`` (e.g. ``"kernels/paged_attention/"``)."""
    prev = _HANDLERS.get(path_fragment)
    if prev is not None and prev is not handler:
        raise ValueError(
            f"pallas cost handler for {path_fragment!r} already registered")
    _HANDLERS[path_fragment] = handler


def lookup_pallas_cost(name_and_src: str) -> Optional[Callable]:
    for frag, handler in _HANDLERS.items():
        if frag in name_and_src:
            return handler
    return None


def registered_pallas_costs() -> Tuple[str, ...]:
    return tuple(sorted(_HANDLERS))


def _nbytes(v) -> int:
    return int(v.aval.size) * int(v.aval.dtype.itemsize)


def uniform_cost(eqn) -> KernelCost:
    """Every operand streamed once, every output written once — correct
    for kernels whose block index maps visit each element exactly once
    (single-sweep grids with no inner re-walk)."""
    return KernelCost(reads=tuple(_nbytes(v) for v in eqn.invars),
                      writes=tuple(_nbytes(v) for v in eqn.outvars))
