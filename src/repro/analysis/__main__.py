"""CLI: audit the serving stack's lowered computations.

``python -m repro.analysis`` builds the default audit matrix — smoke
configs of the default archs x both paged decode backends on one
device, plus a 2-device mesh audit of the Pallas kernel backend (the
process forces two host CPU devices *before* jax initializes, so one
run covers both topologies) — runs every registered pass, and diffs the
error findings against the checked-in ``baseline.json``.

Exit status 0 iff no new findings and no stale baseline entries.

* ``--check-baseline`` is the CI gate (same as the default, spelled
  explicitly so workflows read as intended).
* ``--write-baseline`` regenerates ``baseline.json`` from the current
  findings (use when intentionally accepting or fixing a finding).
* ``--json PATH`` dumps the full findings + per-unit traffic report.
"""
from __future__ import annotations

import os

# Force a 2-device CPU topology before jax initializes any backend:
# the mesh audit needs >1 device, and analysis never executes anything
# so CPU is always the right platform.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import pathlib
import sys

DEFAULT_ARCHS = ("qwen1.5-0.5b", "gemma2-9b", "recurrentgemma-2b",
                 "falcon-mamba-7b")
DEFAULT_BASELINE = pathlib.Path(__file__).parent / "baseline.json"


def build_units(archs, backends, multidevice=True, max_len=32, max_batch=2,
                page_size=8):
    """Audit units for the given matrix (smoke configs, abstract params)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.analysis.artifacts import unit_from_engine
    from repro.configs import get_config
    from repro.dist.sharding import ShardingPolicy
    from repro.models.transformer import TransformerLM
    from repro.serve import PagedCacheConfig, ServeEngine

    units = []
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        for backend in backends:
            eng = ServeEngine(model, params, max_len=max_len,
                              max_batch=max_batch,
                              paged=PagedCacheConfig(page_size=page_size),
                              decode_backend=backend)
            units.append(unit_from_engine(eng, arch))
        # the contiguous cache path (no paging) is a distinct decode
        # computation with its own insert executable — audit it too
        eng = ServeEngine(model, params, max_len=max_len,
                          max_batch=max_batch)
        units.append(unit_from_engine(eng, arch))
    if multidevice:
        if len(jax.devices()) < 2:
            raise RuntimeError(
                "multi-device audit needs 2 devices; run via "
                "python -m repro.analysis (it forces 2 CPU devices) or "
                "pass --no-multidevice")
        mesh = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                    ("data", "model"))
        policy = ShardingPolicy.for_mesh(mesh)
        cfg = get_config(archs[0], smoke=True)
        model = TransformerLM(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        eng = ServeEngine(model, params, max_len=max_len,
                          max_batch=max_batch, mesh=mesh, policy=policy,
                          paged=PagedCacheConfig(page_size=page_size),
                          decode_backend="pallas_paged")
        units.append(unit_from_engine(eng, archs[0]))
    return units


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static traffic audit + lint gate for the serving stack")
    ap.add_argument("--archs", nargs="+", default=list(DEFAULT_ARCHS))
    ap.add_argument("--backends", nargs="+",
                    default=["gather", "pallas_paged"],
                    choices=["gather", "pallas_paged"])
    ap.add_argument("--no-multidevice", dest="multidevice",
                    action="store_false",
                    help="skip the 2-device mesh audit")
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=DEFAULT_BASELINE)
    ap.add_argument("--check-baseline", action="store_true",
                    help="gate on the baseline diff (the default behavior, "
                         "spelled out for CI)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    help="write findings + traffic reports to this path")
    args = ap.parse_args(argv)

    from repro.analysis.registry import (baseline_payload, diff_baseline,
                                         load_baseline, run_passes)
    from repro.analysis.traffic import decode_traffic_report

    units = build_units(args.archs, args.backends,
                        multidevice=args.multidevice)
    findings = run_passes(units)

    reports = {}
    for unit in units:
        if unit.artifact("decode") is None:
            continue
        rep = decode_traffic_report(unit)
        reports[unit.label] = rep
        status = "OK " if rep["match"] else "FAIL"
        print(f"[traffic] {status} {unit.label}: "
              f"{sum(rep['derived'].get(k, 0) for k in rep['expected'])} "
              f"bytes/step across {len(rep['expected'])} gated classes")
    for f in findings:
        print(f"[{f.severity}] {f.key}\n    {f.detail}"
              + (f"\n    at {f.provenance}" if f.provenance else ""))
    if not findings:
        print("no findings")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            {"findings": [f.to_dict() for f in findings],
             "traffic": reports}, indent=2, sort_keys=True))
        print(f"wrote {args.json}")

    if args.write_baseline:
        notes = {}
        if args.baseline.exists():
            notes = load_baseline(args.baseline)
        args.baseline.write_text(
            json.dumps(baseline_payload(findings, notes), indent=2) + "\n")
        print(f"wrote {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline.exists() else {}
    new, fixed = diff_baseline(findings, baseline)
    for f in new:
        print(f"NEW finding (not in baseline): {f.key}")
    for k in fixed:
        print(f"STALE baseline entry (finding fixed — delete it): {k}")
    if new or fixed:
        print("analysis gate: FAIL")
        return 1
    print(f"analysis gate: OK ({len(baseline)} baselined finding(s), "
          f"{len(units)} unit(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
