"""CLI: audit the serving stack's lowered computations.

``python -m repro.analysis`` builds the default audit matrix — smoke
configs of the default archs x both paged decode backends on one
device, plus a 2-device mesh audit of the Pallas kernel backend (the
process forces host CPU devices *before* jax initializes, so one run
covers both topologies) — runs every registered pass, and diffs the
error findings against the checked-in ``baseline.json``.

``--mesh N`` (repeatable) additionally runs the partitioning pass
(:mod:`repro.analysis.partition`): the partition matrix is lowered
under an abstract N-device mesh, GSPMD-partitioned without executing,
and gated on the collective-traffic ledger, the per-device HBM bill
(asserted mesh-size-invariant across every requested size), and the
page-pool locality lint.  Partition finding keys end ``@mesh=N``, so
the baseline diff only scores entries for audited sizes.

Exit status 0 iff no new findings and no stale in-scope baseline
entries.

* ``--check-baseline`` is the CI gate (same as the default, spelled
  explicitly so workflows read as intended).
* ``--write-baseline`` regenerates ``baseline.json`` from the current
  findings (use when intentionally accepting or fixing a finding);
  entries outside the run's mesh scope are preserved verbatim.
* ``--json PATH`` dumps findings + traffic reports + per-mesh
  collective ledgers.
* ``--partition-only`` skips the jaxpr audit matrix (fast path for
  benchmarks that only need the dry-run ledgers).
"""
from __future__ import annotations

import os
import sys


def _forced_device_count(argv) -> int:
    """Host CPU devices this run needs: the largest requested --mesh
    size, floor 2 (the always-on 2-device mesh audit).  Parsed from raw
    argv because jax must be configured before argparse/imports run."""
    vals = []
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            vals.append(argv[i + 1])
        elif a.startswith("--mesh="):
            vals.append(a.split("=", 1)[1])
    n = 2
    for v in vals:
        try:
            n = max(n, int(v))
        except ValueError:
            pass
    return n


# Force the CPU topology before jax initializes any backend: the mesh
# audits need the devices to exist, and analysis never executes
# anything so CPU is always the right platform.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count="
        f"{_forced_device_count(sys.argv)} "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import pathlib

DEFAULT_ARCHS = ("qwen1.5-0.5b", "gemma2-9b", "recurrentgemma-2b",
                 "falcon-mamba-7b")
DEFAULT_BASELINE = pathlib.Path(__file__).parent / "baseline.json"


def build_units(archs, backends, multidevice=True, max_len=32, max_batch=2,
                page_size=8):
    """Audit units for the given matrix (smoke configs, abstract params)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.analysis.artifacts import unit_from_engine
    from repro.configs import get_config
    from repro.dist.sharding import ShardingPolicy
    from repro.models.transformer import TransformerLM
    from repro.serve import PagedCacheConfig, ServeEngine

    units = []
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        for backend in backends:
            eng = ServeEngine(model, params, max_len=max_len,
                              max_batch=max_batch,
                              paged=PagedCacheConfig(page_size=page_size),
                              decode_backend=backend)
            units.append(unit_from_engine(eng, arch))
        # the contiguous cache path (no paging) is a distinct decode
        # computation with its own insert executable — audit it too
        eng = ServeEngine(model, params, max_len=max_len,
                          max_batch=max_batch)
        units.append(unit_from_engine(eng, arch))
    if multidevice:
        if len(jax.devices()) < 2:
            raise RuntimeError(
                "multi-device audit needs 2 devices; run via "
                "python -m repro.analysis (it forces 2 CPU devices) or "
                "pass --no-multidevice")
        mesh = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                    ("data", "model"))
        policy = ShardingPolicy.for_mesh(mesh)
        cfg = get_config(archs[0], smoke=True)
        model = TransformerLM(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        eng = ServeEngine(model, params, max_len=max_len,
                          max_batch=max_batch, mesh=mesh, policy=policy,
                          paged=PagedCacheConfig(page_size=page_size),
                          decode_backend="pallas_paged")
        units.append(unit_from_engine(eng, archs[0]))
    return units


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static traffic audit + lint gate for the serving stack")
    ap.add_argument("--archs", nargs="+", default=list(DEFAULT_ARCHS))
    ap.add_argument("--backends", nargs="+",
                    default=["gather", "pallas_paged"],
                    choices=["gather", "pallas_paged"])
    ap.add_argument("--no-multidevice", dest="multidevice",
                    action="store_false",
                    help="skip the 2-device mesh audit")
    ap.add_argument("--mesh", action="append", type=int, default=[],
                    metavar="N",
                    help="run the abstract-mesh partitioning pass at N "
                         "devices (repeatable; sizes are also cross-"
                         "checked for per-device invariance)")
    ap.add_argument("--partition-archs", nargs="+", default=None,
                    help="archs for the partition matrix (default: one "
                         "KV-pool arch + one state-pool arch)")
    ap.add_argument("--partition-only", action="store_true",
                    help="skip the jaxpr audit matrix; run only the "
                         "--mesh partitioning pass")
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=DEFAULT_BASELINE)
    ap.add_argument("--check-baseline", action="store_true",
                    help="gate on the baseline diff (the default behavior, "
                         "spelled out for CI)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    help="write findings + traffic reports to this path")
    args = ap.parse_args(argv)

    from repro.analysis.registry import (baseline_payload, diff_baseline,
                                         key_in_scope, load_baseline,
                                         run_passes)
    from repro.analysis.traffic import GATED_CLASSES, decode_traffic_report

    if args.partition_only and not args.mesh:
        ap.error("--partition-only needs at least one --mesh size")

    units = [] if args.partition_only else build_units(
        args.archs, args.backends, multidevice=args.multidevice)
    findings = run_passes(units) if units else []

    reports = {}
    for unit in units:
        if unit.artifact("decode") is None:
            continue
        rep = decode_traffic_report(unit)
        reports[unit.label] = rep
        status = "OK " if rep["match"] else "FAIL"
        print(f"[traffic] {status} {unit.label}: "
              f"{sum(rep['derived'].get(k, 0) for k in rep['expected'])} "
              f"bytes/step across {len(rep['expected'])} gated classes")

    partition_units = []
    audited_meshes = sorted(set(args.mesh))
    # scope_archs narrows meshed-key staleness to the archs this run
    # actually partitioned: `--partition-archs qwen... --mesh 8` must
    # not declare the other archs' @mesh=8 entries fixed
    scope_archs = None
    if audited_meshes:
        from repro.analysis.partition import (PARTITION_ARCHS,
                                              build_partition_units,
                                              invariance_findings,
                                              partition_findings)
        scope_archs = tuple(args.partition_archs or PARTITION_ARCHS)
        partition_units = build_partition_units(
            scope_archs, audited_meshes)
        for u in partition_units:
            findings.extend(partition_findings(u))
            wire = sum(row["wire_bytes_per_device"]
                       for rows in u.ledger().values() for row in rows)
            per_dev = sum(u.bill["per_device"].get(k, 0)
                          for k in GATED_CLASSES)
            n_col = sum(len(c) for c in u.collectives.values())
            print(f"[partition] {u.label}: {n_col} collectives "
                  f"({wire:,} wire bytes/device), per-device decode "
                  f"bill {per_dev:,} bytes/step")
        findings.extend(invariance_findings(partition_units))

    for f in findings:
        print(f"[{f.severity}] {f.key}\n    {f.detail}"
              + (f"\n    at {f.provenance}" if f.provenance else ""))
    if not findings:
        print("no findings")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            {"findings": [f.to_dict() for f in findings],
             "traffic": reports,
             "partition": {u.label: u.to_dict()
                           for u in partition_units}},
            indent=2, sort_keys=True))
        print(f"wrote {args.json}")

    mesh_scope = set(audited_meshes)
    unmeshed_in_scope = not args.partition_only
    if args.write_baseline:
        notes = {}
        if args.baseline.exists():
            notes = load_baseline(args.baseline)
        # keep entries this run could not have reproduced (unaudited
        # mesh sizes / skipped jaxpr matrix) instead of dropping them
        preserve = {k: v for k, v in notes.items()
                    if not key_in_scope(k, mesh_scope, unmeshed_in_scope,
                                        scope_archs)}
        # default notes for brand-new entries carry the provenance so
        # the baseline stays reviewable without rerunning the audit
        for f in findings:
            if f.severity == "error" and f.key not in notes:
                notes[f.key] = (f"{f.detail}"
                                + (f" [{f.provenance}]" if f.provenance
                                   else ""))
        args.baseline.write_text(
            json.dumps(baseline_payload(findings, notes, preserve),
                       indent=2) + "\n")
        print(f"wrote {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline.exists() else {}
    new, fixed = diff_baseline(findings, baseline, mesh_scope,
                               unmeshed_in_scope, scope_archs)
    for f in new:
        print(f"NEW finding (not in baseline): {f.key}")
    for k in fixed:
        print(f"STALE baseline entry (finding fixed — delete it): {k}")
    if new or fixed:
        print("analysis gate: FAIL")
        return 1
    scope = sum(1 for k in baseline
                if key_in_scope(k, mesh_scope, unmeshed_in_scope,
                                scope_archs))
    print(f"analysis gate: OK ({scope}/{len(baseline)} baselined "
          f"finding(s) in scope, {len(units)} audit unit(s), "
          f"{len(partition_units)} partition unit(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
