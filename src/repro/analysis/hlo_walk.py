"""Collective-op walker over partitioned HLO text.

This jaxlib exposes no structured HLO instruction API (``HloModule``
gives ``computations()`` and ``to_string()`` only), so the walker
parses ``compiled.as_text()`` line by line, extracting exactly the
communication instructions GSPMD inserts: ``all-gather``,
``all-reduce``, ``reduce-scatter``, ``all-to-all`` and
``collective-permute`` (plus their ``-start``/``-done`` async split —
a started op is counted once, its ``-done`` is skipped).  Everything
else in the module is device-local and therefore invisible to the
cross-device traffic ledger.

Per collective the walker recovers

* result/operand shapes (dtype + dims, layout annotations stripped),
* the replica grouping, in both the explicit ``{{0,1},{2,3}}`` and the
  iota ``[4,2]<=[2,4]T(1,0)`` form (4 groups of 2),
* jax provenance from the ``metadata`` field (``op_name`` carries the
  eqn path, e.g. ``jit(decode)/.../gather``; ``source_file``/
  ``source_line`` point into the model source), and
* exact wire bytes per device under the standard ring schedules:
  all-gather moves ``out*(g-1)/g`` through every device, reduce-scatter
  ``in*(g-1)/g``, all-reduce ``2*in*(g-1)/g`` (reduce-scatter +
  all-gather), all-to-all ``in*(g-1)/g``, collective-permute ``in``.
  All integer-exact: shard sizes divide by construction.

:func:`classify_collective` then attributes each op to the tensor
family it moves — the page-pool classes (``kv_pool``/``state_pool``)
are the ones the locality lint gates — using dtype (integer collectives
are block-table/length/index ``meta`` traffic) and provenance (the
paged-attention kernel's emulated body, ``models/attention.py`` gather/
scatter sites, the recurrent-state modules, the unembed matmul).
"""
from __future__ import annotations

import dataclasses
import posixpath
import re
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Shape", "Collective", "parse_collectives",
           "classify_collective", "ledger_rows",
           "COLLECTIVE_KINDS", "POOL_CLASSES", "TENSOR_CLASSES"]

#: canonical collective kinds (async ``-start`` forms fold into these)
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

#: ledger classes whose presence the locality lint gates
POOL_CLASSES = ("kv_pool", "state_pool")

#: full taxonomy a collective can be attributed to
TENSOR_CLASSES = ("kv_pool", "state_pool", "kv", "state", "params",
                  "logits", "meta", "activation", "other")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_INT_DTYPES = frozenset(("pred", "s4", "u4", "s8", "u8", "s16", "u16",
                         "s32", "u32", "s64", "u64"))


@dataclasses.dataclass(frozen=True)
class Shape:
    """One array shape in an HLO type (layout stripped)."""

    dtype: str
    dims: Tuple[int, ...]

    @property
    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def byte_size(self) -> int:
        return self.size * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass(frozen=True)
class Collective:
    """One GSPMD communication instruction from a partitioned module."""

    kind: str                          # canonical (no -start suffix)
    name: str                          # %all-gather.150
    result_shapes: Tuple[Shape, ...]   # tuple results flattened
    operand_shapes: Tuple[Shape, ...]
    n_groups: int                      # 0 when no replica_groups printed
    group_size: int
    op_name: str = ""
    source_file: str = ""
    source_line: int = 0
    is_async: bool = False

    @property
    def result_bytes(self) -> int:
        if self.is_async and len(self.result_shapes) > 1:
            # async-start results are (operand, result[, contexts]) —
            # the gathered payload is the last array element
            return self.result_shapes[-1].byte_size
        return sum(s.byte_size for s in self.result_shapes)

    @property
    def operand_bytes(self) -> int:
        return sum(s.byte_size for s in self.operand_shapes)

    def wire_bytes_per_device(self) -> int:
        """Exact per-device wire bytes under a ring schedule."""
        g = self.group_size
        if self.kind == "collective-permute":
            return self.operand_bytes
        if g <= 1:
            return 0
        if self.kind == "all-gather":
            return self.result_bytes * (g - 1) // g
        if self.kind == "all-reduce":
            return 2 * self.operand_bytes * (g - 1) // g
        # reduce-scatter / all-to-all
        return self.operand_bytes * (g - 1) // g

    def to_dict(self) -> dict:
        return {
            "kind": self.kind, "name": self.name,
            "result": [f"{s.dtype}{list(s.dims)}" for s in self.result_shapes],
            "operands": [f"{s.dtype}{list(s.dims)}"
                         for s in self.operand_shapes],
            "n_groups": self.n_groups, "group_size": self.group_size,
            "wire_bytes_per_device": self.wire_bytes_per_device(),
            "op_name": self.op_name,
            "source": (f"{self.source_file}:{self.source_line}"
                       if self.source_file else ""),
        }


_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_HEAD_RE = re.compile(
    r"(%[\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z]+\d*\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"((?:all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?)\(")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})?\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')
_SOURCE_FILE_RE = re.compile(r'source_file="([^"]*)"')
_SOURCE_LINE_RE = re.compile(r"source_line=(\d+)")


def _parse_shapes(text: str) -> Tuple[Shape, ...]:
    return tuple(Shape(m.group(1),
                       tuple(int(d) for d in m.group(2).split(",") if d))
                 for m in _SHAPE_RE.finditer(text))


def _operand_region(line: str, start: int) -> str:
    """The text inside the collective's argument parens (layouts use
    braces, so only ``T(1,0)``-style parens nest — a depth scan is
    exact)."""
    depth = 0
    for i in range(start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i]
    return line[start + 1:]


def _parse_groups(line: str, n_devices: Optional[int]) -> Tuple[int, int]:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(1)), int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        inner = m.group(1)
        if not inner:
            # replica_groups={}: one group over every participant
            return (1, n_devices or 0)
        groups = re.findall(r"\{([\d, ]*)\}", inner)
        sizes = [len([t for t in g.split(",") if t.strip()]) for g in groups]
        return len(groups), max(sizes) if sizes else 0
    return 0, 0


def parse_collectives(hlo_text: str,
                      n_devices: Optional[int] = None) -> List[Collective]:
    """Every communication instruction in a partitioned HLO module.

    ``n_devices`` resolves the empty ``replica_groups={}`` form (one
    group spanning all participants).  ``-done`` instructions are
    skipped — their ``-start`` carries the shapes and metadata.
    """
    out: List[Collective] = []
    for line in hlo_text.splitlines():
        # ``-done`` ops never match _HEAD_RE (the kind must be followed
        # directly by its open paren), so start/done pairs count once
        m = _HEAD_RE.search(line)
        if m is None:
            continue
        name, result_txt, kind = m.group(1), m.group(2), m.group(3)
        is_async = kind.endswith("-start")
        canonical = kind[:-len("-start")] if is_async else kind
        operands = _operand_region(line, line.index("(", m.end(3)))
        n_groups, group_size = _parse_groups(line, n_devices)
        src = _SOURCE_FILE_RE.search(line)
        ln = _SOURCE_LINE_RE.search(line)
        opn = _OP_NAME_RE.search(line)
        out.append(Collective(
            kind=canonical, name=name,
            result_shapes=_parse_shapes(result_txt),
            operand_shapes=_parse_shapes(operands),
            n_groups=n_groups, group_size=group_size,
            op_name=opn.group(1) if opn else "",
            source_file=src.group(1) if src else "",
            source_line=int(ln.group(1)) if ln else 0,
            is_async=is_async))
    return out


# ------------------------------------------------------------ classification
#: model source files that own each cache family.  Paged engines route
#: these sites at pool buffers; contiguous engines at the [B, L, ...]
#: cache — the mode picks which class the site's traffic lands in.
_KV_SOURCES = ("attention.py",)
_STATE_SOURCES = ("rglru.py", "ssm.py")
_PARAM_SOURCES = ("layers.py", "moe.py", "frontends.py")


def classify_collective(c: Collective, mode: str,
                        pool_dims: Optional[Dict[Tuple[int, ...], str]]
                        = None) -> str:
    """Attribute a collective to the tensor family it moves.

    ``mode`` is the *artifact's cache layout* (``contiguous`` /
    ``gather`` / ``pallas_paged``): the same attention/state source
    sites address page pools in paged modes and the contiguous cache
    otherwise (prefill always materializes a contiguous cache, so its
    caller passes ``contiguous`` regardless of the engine backend).
    Integer collectives are ``meta`` (block tables, lengths, scatter
    indices) regardless of site — O(pages) indirection noise, never
    payload.

    ``pool_dims`` maps known pool-buffer shapes (dims tuples) to their
    pool class: a collective whose operand or result *is* a pool buffer
    is classified as that pool even without provenance metadata, so a
    full-pool materialization can never hide behind a missing
    ``op_name``.  Float collectives with no source metadata at all are
    GSPMD reshards of unnamed intermediates — ``activation``.
    """
    shapes = tuple(c.operand_shapes) + tuple(c.result_shapes)
    if shapes and all(s.dtype in _INT_DTYPES for s in shapes):
        return "meta"
    if pool_dims:
        for s in shapes:
            cls = pool_dims.get(s.dims)
            if cls is not None:
                return cls
    paged = mode != "contiguous"
    base = posixpath.basename(c.source_file.replace("\\", "/"))
    if "paged_decode_attention" in c.op_name or "/kernels/" in c.source_file:
        return "kv_pool"
    if "unembed" in c.op_name or "lm_head" in c.op_name:
        return "logits"
    if base in _KV_SOURCES:
        return "kv_pool" if paged else "kv"
    if base in _STATE_SOURCES:
        return "state_pool" if paged else "state"
    if base == "transformer.py" and (
            "dynamic_update_slice" in c.op_name or "scatter" in c.op_name):
        # the stacked-layer cache write site (scan body DUS into the
        # per-layer cache stack) — cache payload, not parameters
        return "kv_pool" if paged else "kv"
    if base in _PARAM_SOURCES or base == "transformer.py":
        return "params"
    if not c.source_file and not c.op_name:
        return "activation"
    return "other"


def ledger_rows(collectives: Sequence[Collective], mode: str,
                pool_dims: Optional[Dict[Tuple[int, ...], str]] = None
                ) -> List[dict]:
    """Aggregate a module's collectives into ledger rows, one per
    (kind, class, source site): instruction count, total wire bytes per
    device, and one representative provenance string."""
    agg: Dict[Tuple[str, str, str], dict] = {}
    for c in collectives:
        cls = classify_collective(c, mode, pool_dims)
        if "paged_decode_attention" in c.op_name:
            site = "kernels/paged_attention"
        else:
            site = posixpath.basename(c.source_file.replace("\\", "/")) \
                or "unattributed"
        row = agg.setdefault((c.kind, cls, site), {
            "kind": c.kind, "class": cls, "site": site,
            "count": 0, "wire_bytes_per_device": 0,
            "op_name": c.op_name,
            "source": (f"{c.source_file}:{c.source_line}"
                       if c.source_file else "")})
        row["count"] += 1
        row["wire_bytes_per_device"] += c.wire_bytes_per_device()
    return [agg[k] for k in sorted(agg)]
