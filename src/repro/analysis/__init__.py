"""Static analysis of the serving stack's lowered computations.

The paper's RTC argument needs byte-accurate knowledge of DRAM traffic,
but ``serve/telemetry.py``'s :class:`TrafficModel` is hand-derived
arithmetic.  This package closes that gap *statically*: it walks the
ClosedJaxprs of the engine's lowered prefill/decode executables — no
execution, abstract params suffice — and machine-checks what XLA will
actually move against what the analytic model claims.

Design
======

**Audit units and artifacts** (:mod:`.artifacts`).  One
:class:`~repro.analysis.artifacts.AuditUnit` per engine configuration
(arch x decode backend x topology) captures each lowered executable
(decode step, top prefill bucket, contiguous slot-insert) as an
:class:`~repro.analysis.artifacts.Artifact`: the traced ClosedJaxpr,
per-invar taint seeds derived from the argument pytree paths, donation
flags from ``jitted.lower(...).args_info``, and argument
PartitionSpecs.  Everything is obtained from abstract arguments, so the
CLI audits engines built with ``jax.eval_shape``'d params.

**Pass registry** (:mod:`.registry`).  A pass is ``fn(unit) ->
[Finding]`` registered under a stable name; ``run_passes`` runs all of
them over all units.  Findings carry a deterministic key
(``pass:code:subject``) — the unit of baseline accounting.

**Traffic auditor** (:mod:`.jaxpr_walk`, :mod:`.traffic`).  A taint
walker bills memory-moving equations exactly: structural ops are free
views, compute reads of HBM-resident operands (cache leaves, params,
the gather backend's materialized view) bill their aval bytes per use,
pool gathers bill the view's read *and* write, scatters on resident
buffers bill exactly their update bytes and keep the in-place chain,
scans multiply their body by the trip count, and cache outvars that did
not stay in-place bill as fresh full writes.  The derived per-class
bytes must equal ``TrafficModel.static_decode_classes`` at full
occupancy, class for class — ``traffic-drift`` findings are never
baselined, so accounting drift between telemetry and the lowered
computation fails CI statically.

**Cost-handler protocol** (:mod:`.costs`).  ``pallas_call`` is opaque
to the walker, so each kernel's ``repro.kernels.*.ops`` module
registers ``handler(eqn) -> KernelCost`` (per-operand HBM bytes derived
from operand avals and the equation's grid), keyed by a source-path
fragment of the kernel body.  The walker classifies handler bytes by
operand taint; a pallas call with no handler is itself an error
finding, which is what keeps cost handlers from drifting from their
kernels (the kernels CI job runs ``--check-baseline``).

**Lints** (:mod:`.lints`).  Sharding: detects GSPMD all-gathers forced
around the opaque paged-attention kernel on a mesh and pool page dims
that lost their sharding.  Pallas sites inside a ``shard_map`` body are
marked ``manual`` by the walker and exempt — their operands are
already device-local — so any *new* unmapped occurrence fails CI.
Hygiene: f64/weak-type promotion, closure-captured constants > 1 MiB,
host-sync callbacks, and cache arguments whose lowered executables do
not donate them (an un-donated cache is a full copy per step that the
byte accounting would silently miss).

**Partitioning pass** (:mod:`.partition`, :mod:`.hlo_walk`).  The jaxpr
walk sees the *global* computation; production scale needs the
*per-device* story.  The partitioning pass lowers the engine's decode
step, top prefill bucket, and contiguous insert under abstract meshes
of 2/8/64/512 devices (``jax.sharding.AbstractMesh`` describes the
mesh; ``repro.dist.sharding.as_concrete_mesh`` binds it to forced host
CPU devices because this jax cannot lower on an abstract mesh, and
``jit.lower(...).compile()`` runs GSPMD without executing — compile
cost is O(module), independent of mesh size).  :mod:`.hlo_walk` then
parses the partitioned HLO text (no structured instruction API exists
in this jaxlib) for every ``all-gather``/``all-reduce``/
``reduce-scatter``/``all-to-all``/``collective-permute``, with exact
ring-schedule wire bytes from the sharded shapes and a tensor-family
taxonomy from dtype + jax provenance metadata.  Three gates come out:
the **collective ledger** (every collective attributed to the tensor it
moves), the **per-device HBM bill** (``static_decode_classes`` split by
the cache shardings, asserted mesh-size-invariant class-for-class — the
audit geometry weak-scales at one slot + five pool pages per device, so
any per-device growth is a locality regression), and the **page-pool
locality lint** (``partition:pool-collective:...@mesh=N`` error
findings for every collective moving ``kv_pool``/``state_pool`` pages).
PR 8's device-local ``shard_map`` decode drained that family entirely:
``baseline.json`` is empty, so a pool byte moving cross-device at any
audited mesh size fails the gate outright, and the per-device HBM bill
is asserted mesh-size-invariant with the audit geometry weak-scaling
at one slot + four resident pages per device.

**shard_map rule** (:mod:`.jaxpr_walk`).  The walker descends into
``shard_map`` equations with the body's *per-shard* avals and
multiplies its bills by the shard count (mesh axes not in ``auto``),
so per-shard bytes x N equals the exact global bill for the gated
traffic classes; contained Pallas sites are marked ``manual`` for the
sharding lint.

**Baseline policy** (:mod:`.registry`, ``baseline.json``).  Error
findings diff against the checked-in allowlist (empty since PR 8): a
finding not in the baseline fails (regression), and a baseline entry
no longer produced also fails (the fix must shrink the baseline in the
same change — the PR 8 drain deleted all 48 pool-collective entries
plus the PR 6 GSPMD-gather entry this way).
``info`` findings never gate.  Mesh-parameterized keys (``...@mesh=N``)
are only scored when mesh N was audited — a ``--mesh 2`` run can
neither confirm nor retire the ``@mesh=512`` family, and
``--write-baseline`` preserves out-of-scope entries verbatim.
``python -m repro.analysis --write-baseline`` regenerates the file;
``--check-baseline`` is the CI gate.

**Prefix sharing** (PR 10) changes nothing the auditor sees, by
construction: sharing is host-side page-table bookkeeping (content
hashes, refcounts, block-table values), and the lowered prefill/decode
executables are byte-for-byte the ones audited here — a dedup-attach
admission runs the same prefill executable with its scatter redirected
to the DUMP row, and a COW fork reuses the audited contiguous-insert
machinery's page-copy pattern.  The static per-class bills therefore
remain the *unshared* worst case; the shared-page saving is a
telemetry/trace-level row-set credit (``TrafficModel.prefix_hit_*``,
``PageAccessTrace`` per-step dedup), never a change to what XLA moves
per invocation.  The traffic-drift gate keeps holding exactly because
sharing does not touch the lowered computation.

Run ``python -m repro.analysis`` for the default audit matrix (4 archs
x both paged decode backends, plus a forced-2-device mesh audit of the
kernel backend); add ``--mesh 8 --mesh 64 ...`` for the partitioning
pass.
"""
from repro.analysis.artifacts import (Artifact, AuditUnit,
                                      sharded_leaf_factors, unit_from_engine)
from repro.analysis.costs import KernelCost, register_pallas_cost
from repro.analysis.hlo_walk import (Collective, classify_collective,
                                     ledger_rows, parse_collectives)
from repro.analysis.jaxpr_walk import Taint, walk_jaxpr
from repro.analysis.registry import (Finding, diff_baseline, key_mesh_size,
                                     load_baseline, register_pass,
                                     run_passes)
from repro.analysis.traffic import decode_traffic_report, split_per_device
import repro.analysis.lints    # noqa: F401  (registers sharding/hygiene)

__all__ = ["Artifact", "AuditUnit", "unit_from_engine", "KernelCost",
           "register_pallas_cost", "Taint", "walk_jaxpr", "Finding",
           "diff_baseline", "load_baseline", "register_pass", "run_passes",
           "decode_traffic_report", "Collective", "classify_collective",
           "ledger_rows", "parse_collectives", "sharded_leaf_factors",
           "split_per_device", "key_mesh_size"]
