"""Mesh-scale partitioning pass: abstract-mesh SPMD lowering + gates.

The repo executes on at most 2 CPU devices, but ROADMAP item 3 needs
evidence at production mesh sizes (8/64/512).  This module produces
that evidence statically: each :class:`PartitionUnit` lowers one engine
configuration's executables (decode step, top prefill bucket,
contiguous insert) under an abstract ``data``-major mesh of N devices,
runs GSPMD partitioning via ``jit.lower(...).compile()`` — nothing
executes; params are ``jax.eval_shape`` abstractions and the compile is
O(module), independent of N — and walks the partitioned HLO with
:mod:`.hlo_walk`.

The mesh is *described* with ``jax.sharding.AbstractMesh``; this jax
version cannot lower on one (``_device_assignment`` is unimplemented),
so :func:`repro.dist.sharding.as_concrete_mesh` binds it to compile-only
host CPU devices, which ``python -m repro.analysis`` forces into
existence (``--xla_force_host_platform_device_count``) before jax
initializes.

Three machine checks come out of each unit:

* a **collective-traffic ledger** — every GSPMD-inserted collective,
  classified by the tensor family it moves with exact per-device wire
  bytes (:func:`repro.analysis.hlo_walk.ledger_rows`);
* a **per-device HBM bill** — ``TrafficModel.static_decode_classes``
  split by the decode step's cache shardings
  (:func:`repro.analysis.traffic.split_per_device`), which
  :func:`invariance_findings` asserts is mesh-size-invariant
  class-for-class across every audited mesh (the audit geometry weak-
  scales: one slot, six KV pages and three state pages per device, so
  the per-device split must not move);
* a **locality lint** — any collective moving a page-pool class
  (``kv_pool``/``state_pool``) is an error finding keyed
  ``partition:pool-collective:...@mesh=N``.  The device-local
  ``shard_map`` decode layout (``PagedCacheConfig.shards``;
  :func:`repro.serve.engine.build_decode_step`) drained the whole
  mesh-parameterized family from ``baseline.json``, so any occurrence
  now fails the gate outright.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.analysis.hlo_walk import (Collective, POOL_CLASSES,
                                     ledger_rows, parse_collectives)
from repro.analysis.registry import Finding
from repro.analysis.traffic import GATED_CLASSES, split_per_device

__all__ = ["PartitionUnit", "abstract_mesh", "partition_unit",
           "build_partition_units", "partition_findings",
           "invariance_findings", "PARTITION_ARCHS", "PARTITION_MODES",
           "SLOTS_PER_DEVICE", "PAGES_PER_DEVICE", "STATE_PAGES_PER_DEVICE",
           "PAGE_SIZE", "MAX_LEN"]

# Weak-scaling audit geometry: per-device shares are constant, so the
# per-device bill is the invariant under mesh growth.  One decode slot,
# six KV pool pages, and three state pages per device, in the
# device-local layout (``PagedCacheConfig.shards = N``): every device
# owns its own reserved ZERO/DUMP pair plus exactly the resident pages
# of its slot, so both pool page dims are N-divisible AND each shard
# clears the per-shard slot floor — page_size 8, context 32 = 4 pages
# per slot leaves each device 4 resident KV pages (= the floor) and
# 1 state slot behind its 2 reserved state pages.
SLOTS_PER_DEVICE = 1
PAGES_PER_DEVICE = 6
STATE_PAGES_PER_DEVICE = 3
PAGE_SIZE = 8
MAX_LEN = 32

#: default matrix: one attention arch (KV pools) + one recurrent arch
#: (conv/h state pools) x every decode cache mode
PARTITION_ARCHS = ("qwen1.5-0.5b", "recurrentgemma-2b")
PARTITION_MODES = ("contiguous", "gather", "pallas_paged")


def abstract_mesh(n: int):
    """The N-device serving mesh as an ``AbstractMesh`` description
    (data-parallel over slots/pages; the model axis stays 1 — smoke
    configs have too few KV heads to fill one)."""
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", int(n)), ("model", 1)))


@dataclasses.dataclass
class PartitionUnit:
    """One engine configuration partitioned at one abstract mesh size."""

    label: str                    # '<arch>/<mode>/mesh<N>'
    cfg_name: str
    mode: str                     # 'contiguous' | 'gather' | 'pallas_paged'
    mesh_size: int
    live: int                     # decode batch (slots) the step lowers for
    ctx: int                      # per-slot context capacity
    collectives: Dict[str, Tuple[Collective, ...]]   # per artifact name
    bill: dict                    # {'global', 'per_device', 'leaf_factors'}
    problems: List[str] = dataclasses.field(default_factory=list)
    #: known pool-buffer shapes -> pool class, so a metadata-less
    #: collective whose operand *is* a pool buffer still classifies
    pool_dims: Dict[Tuple[int, ...], str] = \
        dataclasses.field(default_factory=dict)

    def artifact_mode(self, name: str) -> str:
        """Cache layout of one artifact: prefill/insert always build a
        contiguous cache, only the decode step addresses the pools."""
        return self.mode if name == "decode" else "contiguous"

    def ledger(self) -> Dict[str, List[dict]]:
        return {name: ledger_rows(
                    cols, self.artifact_mode(name),
                    self.pool_dims if name == "decode" else None)
                for name, cols in self.collectives.items()}

    def to_dict(self) -> dict:
        return {"label": self.label, "mesh_size": self.mesh_size,
                "live": self.live, "ctx": self.ctx,
                "bill": self.bill, "problems": list(self.problems),
                "ledger": self.ledger(),
                "collectives": {
                    name: [c.to_dict() for c in cols]
                    for name, cols in self.collectives.items()}}


#: cache pytree leaf names that are pool buffers -> their pool class
_POOL_LEAVES = {"kp": "kv_pool", "vp": "kv_pool",
                "conv_p": "state_pool", "h_p": "state_pool"}


def _pool_dims(entry) -> Dict[Tuple[int, ...], str]:
    """Shape fingerprints of every pool buffer in a decode entry: the
    global dims, the per-device shard dims, and (for stacked layer-group
    leaves) their trailing per-layer dims.  :func:`classify_collective`
    uses these to pin metadata-less collectives that move a whole pool.
    """
    import jax

    from repro.analysis.artifacts import leaf_name

    dims: Dict[Tuple[int, ...], str] = {}
    for argnum, arg in enumerate(entry["args"]):
        if entry["roles"].get(argnum) != "cache":
            continue
        sh = entry["shardings"][argnum] \
            if entry.get("shardings") is not None else None
        leaves = jax.tree_util.tree_flatten_with_path(arg)[0]
        sh_leaves = (jax.tree_util.tree_leaves(sh)
                     if sh is not None else [None] * len(leaves))
        for (path, leaf), s in zip(leaves, sh_leaves):
            cls = _POOL_LEAVES.get(leaf_name(path))
            if cls is None:
                continue
            shapes = [tuple(int(d) for d in leaf.shape)]
            if s is not None and hasattr(s, "shard_shape"):
                shapes.append(tuple(int(d)
                                    for d in s.shard_shape(shapes[0])))
            for shape in list(shapes):
                if len(shape) > 2:
                    shapes.append(shape[1:])   # per-layer slice of a stack
            for shape in shapes:
                dims.setdefault(shape, cls)
    return dims


def partition_unit(model, params, cfg_name: str, mode: str,
                   n: int) -> PartitionUnit:
    """Lower one (arch, mode) engine under an N-device abstract mesh
    and walk the partitioned modules.  ``params`` are abstract."""
    from repro.analysis.artifacts import sharded_leaf_factors
    from repro.serve import PagedCacheConfig, ServeEngine
    from repro.serve.paging import RESERVED_PAGES
    from repro.serve.telemetry import TrafficModel

    paged = None
    if mode != "contiguous":
        # Device-local layout: n_pages = resident + n * RESERVED lands on
        # exactly PAGES_PER_DEVICE * n, so the pool page dim is data-axis
        # divisible (page_spec shards it) and the shard_map decode step
        # addresses only the local extent at every audited mesh size.
        paged = PagedCacheConfig(
            page_size=PAGE_SIZE,
            resident_pages=(PAGES_PER_DEVICE - RESERVED_PAGES) * n,
            state_pages=STATE_PAGES_PER_DEVICE * n,
            shards=n)
    eng = ServeEngine(model, params, max_len=MAX_LEN,
                      max_batch=SLOTS_PER_DEVICE * n,
                      paged=paged,
                      decode_backend=mode if paged is not None else "gather")
    entries = eng.lowered_artifacts(mesh=abstract_mesh(n))

    collectives: Dict[str, Tuple[Collective, ...]] = {}
    decode_entry = None
    for entry in entries:
        compiled = entry["fn"].lower(*entry["args"]).compile()
        collectives[entry["name"]] = tuple(
            parse_collectives(compiled.as_text(), n_devices=n))
        if entry["name"] == "decode":
            decode_entry = entry

    factors, factor_problems = sharded_leaf_factors(
        decode_entry["args"], decode_entry["shardings"],
        decode_entry["roles"])
    page = paged.page_size if paged is not None else 0
    traffic = TrafficModel.from_config(model.cfg, eng.max_ctx,
                                       page_size=page)
    expected = traffic.static_decode_classes(
        [eng.max_ctx] * eng.max_batch, mode)
    per_device, split_problems = split_per_device(expected, factors, mode)
    return PartitionUnit(
        label=f"{cfg_name}/{mode}/mesh{n}", cfg_name=cfg_name, mode=mode,
        mesh_size=n, live=eng.max_batch, ctx=eng.max_ctx,
        collectives=collectives,
        bill={"global": expected, "per_device": per_device,
              "leaf_factors": factors},
        problems=factor_problems + split_problems,
        pool_dims=_pool_dims(decode_entry))


def build_partition_units(archs: Sequence[str], meshes: Sequence[int],
                          modes: Sequence[str] = PARTITION_MODES
                          ) -> List[PartitionUnit]:
    """The partition matrix: archs x modes x mesh sizes (sorted)."""
    import jax

    from repro.configs import get_config
    from repro.models.transformer import TransformerLM

    units = []
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        for mode in modes:
            for n in sorted(set(int(m) for m in meshes)):
                units.append(partition_unit(model, params, arch, mode, n))
    return units


def partition_findings(unit: PartitionUnit) -> List[Finding]:
    """Ledger + locality-lint findings for one partition unit.

    Pool-class collectives (and unclassified float collectives, which
    would otherwise hide pool traffic behind a renamed source site) are
    errors gated against the baseline; payload collectives on
    non-pool families (contiguous-cache appends, logits/param
    movement) are reported as info; integer ``meta`` indirection stays
    in the JSON ledger only.
    """
    findings: List[Finding] = []
    n = unit.mesh_size
    ledger = unit.ledger()
    for art_name in sorted(ledger):
        for row in ledger[art_name]:
            cls = row["class"]
            subject = (f"{unit.cfg_name}/{unit.mode}:{art_name}:"
                       f"{row['kind']}:{cls}:{row['site']}@mesh={n}")
            prov = " ".join(p for p in
                            (row["op_name"],
                             f"({row['source']})" if row["source"] else "")
                            if p)
            if cls in POOL_CLASSES:
                findings.append(Finding(
                    pass_name="partition", code="pool-collective",
                    subject=subject,
                    detail=(f"{row['count']} {row['kind']}(s) moving "
                            f"{cls} pages cross-device: "
                            f"{row['wire_bytes_per_device']:,} wire "
                            f"bytes/device/step at mesh {n}"),
                    provenance=prov))
            elif cls == "other":
                findings.append(Finding(
                    pass_name="partition", code="unclassified-collective",
                    subject=subject,
                    detail=(f"{row['count']} {row['kind']}(s) moving "
                            f"{row['wire_bytes_per_device']:,} wire "
                            f"bytes/device/step of unattributed float "
                            f"payload at mesh {n} — extend the "
                            f"hlo_walk taxonomy"),
                    provenance=prov))
            elif cls != "meta":
                findings.append(Finding(
                    pass_name="partition", code="collective",
                    subject=subject,
                    detail=(f"{row['count']} {row['kind']}(s) on {cls}: "
                            f"{row['wire_bytes_per_device']:,} wire "
                            f"bytes/device/step at mesh {n}"),
                    provenance=prov, severity="info"))
    for problem in unit.problems:
        findings.append(Finding(
            pass_name="partition", code="indivisible-split",
            subject=f"{unit.cfg_name}/{unit.mode}:decode@mesh={n}",
            detail=problem))
    return findings


def invariance_findings(units: Sequence[PartitionUnit]) -> List[Finding]:
    """Assert the per-device decode bill is mesh-size-invariant.

    For every (arch, mode) audited at 2+ mesh sizes, each gated traffic
    class's per-device bytes must equal the smallest mesh's — any drift
    is an error finding (never baselined: a class whose per-device share
    grows with the mesh is exactly the locality regression ROADMAP
    item 3 forbids).
    """
    by_cfg: Dict[Tuple[str, str], Dict[int, dict]] = {}
    for u in units:
        by_cfg.setdefault((u.cfg_name, u.mode), {})[u.mesh_size] = \
            u.bill["per_device"]
    findings: List[Finding] = []
    for (cfg_name, mode), by_mesh in sorted(by_cfg.items()):
        if len(by_mesh) < 2:
            continue
        ref_n = min(by_mesh)
        ref = by_mesh[ref_n]
        for n in sorted(by_mesh):
            if n == ref_n:
                continue
            for cls in GATED_CLASSES:
                got, want = by_mesh[n].get(cls, 0), ref.get(cls, 0)
                if got != want:
                    findings.append(Finding(
                        pass_name="partition", code="per-device-variance",
                        subject=f"{cfg_name}/{mode}:{cls}@mesh={n}",
                        detail=(f"per-device {cls} = {got} bytes/step at "
                                f"mesh {n} but {want} at mesh {ref_n} — "
                                f"the split is not mesh-size-invariant")))
    return findings
