"""Sharding and compile-hygiene lints over lowered serving artifacts.

Sharding (decode step only, meaningful on >1-device meshes):

* ``gspmd-gather-around-pallas-call`` — a ``pallas_call`` consumes an
  operand that structurally descends from a *sharded* input.  The call
  is opaque to GSPMD, which must all-gather the operand onto every
  device before the kernel and re-shard after — per-step collective
  traffic the byte model does not include.  Calls inside a
  ``shard_map`` region (``PallasSite.manual``) are exempt: their
  operands arrive as device-local shards by construction and GSPMD
  never re-shards them — that is exactly how the paged decode step
  closed this gap (ROADMAP item 3); any *new* unmapped occurrence
  fails CI.
* ``pool-page-dim-unsharded`` — a KV pool leaf whose page dim divides
  the data-axis extent is nevertheless replicated in the lowered
  signature.  The paged cache's whole point on a mesh is that pool
  pages shard; losing that silently multiplies cache footprint by the
  device count.

Hygiene (every artifact):

* ``f64-promotion`` — a float64/complex128 aval anywhere in the lowered
  jaxpr (weak-type creep doubles every byte the traffic model counts).
* ``large-captured-constant`` — closure-captured constants baked into
  the executable above 1 MiB (params must arrive as arguments, or every
  recompile re-embeds them and donation can't apply).
* ``host-sync-point`` — callbacks/infeed primitives that force a device
  sync inside a serving step.
* ``undonated-cache-buffer`` — a cache argument the engine declares as
  step-consumed whose lowered ``args_info`` does not carry donation:
  XLA then copies the full buffer every step, traffic the byte
  accounting (which assumes in-place update) would silently miss.
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from repro.analysis.artifacts import Artifact, AuditUnit
from repro.analysis.registry import Finding, register_pass

__all__ = ["sharding_pass", "hygiene_pass"]

_LARGE_CONST_BYTES = 1 << 20
_HOST_SYNC_PRIMS = ("io_callback", "pure_callback", "debug_callback",
                    "callback", "infeed", "outfeed")


def _src(eqn) -> str:
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return ""


def _iter_eqns(jaxpr) -> Iterator:
    """All equations, recursing into nested jaxprs (incl. kernel bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                yield from _iter_eqns(sub)
            elif hasattr(v, "eqns"):
                yield from _iter_eqns(v)
            elif isinstance(v, (tuple, list)):
                for b in v:
                    inner = getattr(b, "jaxpr", None)
                    if inner is not None and hasattr(inner, "eqns"):
                        yield from _iter_eqns(inner)


def _spec_axes(spec) -> Tuple:
    """Flatten a PartitionSpec's mesh-axis names (ignoring None dims)."""
    axes = []
    for entry in tuple(spec or ()):
        if entry is None:
            continue
        axes.extend(entry if isinstance(entry, tuple) else (entry,))
    return tuple(axes)


def _kernel_key(name_and_src: str) -> str:
    """Stable kernel identity from name_and_src_info: the kernels/<name>
    path fragment when present, else the raw kernel name."""
    marker = "kernels/"
    i = name_and_src.find(marker)
    if i >= 0:
        frag = name_and_src[i:].split("/")
        if len(frag) >= 2:
            return "/".join(frag[:2])
    return name_and_src.split(" ")[0]


@register_pass("sharding")
def sharding_pass(unit: AuditUnit) -> List[Finding]:
    findings: List[Finding] = []
    art = unit.artifact("decode")
    if art is None:
        return findings
    sharded_axes = {a for a, s in unit.axis_sizes.items() if s > 1}
    if not sharded_axes:
        return findings

    def leaf_sharded(flat_index) -> bool:
        spec = art.arg_specs[flat_index]
        return bool(set(_spec_axes(spec)) & sharded_axes)

    res = art.walk()
    for site in res.pallas_sites:
        if site.manual:
            continue      # shard_map body: operands are already local
        offending = []
        for i, taint in enumerate(site.operand_taints):
            if taint is not None and taint.src is not None \
                    and leaf_sharded(taint.src):
                offending.append(
                    f"operand {i} ({taint.cls}, "
                    f"{art.invar_labels[taint.src]}, "
                    f"shape {site.operand_shapes[i]})")
        if offending:
            findings.append(Finding(
                pass_name="sharding", code="gspmd-gather-around-pallas-call",
                subject=f"{unit.label}:decode:{_kernel_key(site.name_and_src)}",
                detail=("GSPMD all-gathers sharded operands around the "
                        "opaque pallas_call: " + "; ".join(offending)),
                provenance=site.name_and_src))

    data_size = 1
    for a in unit.data_axes:
        data_size *= unit.axis_sizes.get(a, 1)
    if data_size > 1:
        for i, (seed, var) in enumerate(zip(art.seeds,
                                            art.closed_jaxpr.jaxpr.invars)):
            if seed is None or seed.cls != "kv_pool":
                continue
            page_dim = len(var.aval.shape) - 4     # [(G,) pages, P, kvh, hd]
            n_pages = var.aval.shape[page_dim]
            if n_pages % data_size:
                continue                           # legitimately replicated
            spec = art.arg_specs[i]
            entry = (tuple(spec)[page_dim]
                     if spec is not None and page_dim < len(tuple(spec))
                     else None)
            entry_axes = (entry if isinstance(entry, tuple)
                          else (entry,) if entry is not None else ())
            if not (set(entry_axes) & sharded_axes):
                findings.append(Finding(
                    pass_name="sharding", code="pool-page-dim-unsharded",
                    subject=f"{unit.label}:decode:{art.invar_labels[i]}",
                    detail=(f"pool leaf {art.invar_labels[i]} has "
                            f"{n_pages} pages divisible by the data-axis "
                            f"extent {data_size} but spec {spec} leaves "
                            f"the page dim replicated")))
    return findings


def _hygiene_artifact(unit: AuditUnit, art: Artifact) -> List[Finding]:
    findings: List[Finding] = []
    subject = f"{unit.label}:{art.name}"

    seen_f64 = set()
    seen_sync = set()
    for eqn in _iter_eqns(art.closed_jaxpr.jaxpr):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None and dt in (np.float64, np.complex128) \
                    and eqn.primitive.name not in seen_f64:
                seen_f64.add(eqn.primitive.name)
                findings.append(Finding(
                    pass_name="hygiene", code="f64-promotion",
                    subject=f"{subject}:{eqn.primitive.name}",
                    detail=(f"{eqn.primitive.name} produces {dt} "
                            f"{getattr(aval, 'shape', ())} — double-width "
                            f"promotion in a lowered serving step"),
                    provenance=_src(eqn)))
        name = eqn.primitive.name
        if name in _HOST_SYNC_PRIMS and name not in seen_sync:
            seen_sync.add(name)
            findings.append(Finding(
                pass_name="hygiene", code="host-sync-point",
                subject=f"{subject}:{name}",
                detail=f"{name} forces a host round-trip inside the step",
                provenance=_src(eqn)))

    for idx, const in enumerate(art.consts):
        nbytes = int(getattr(const, "nbytes", 0) or 0)
        if nbytes > _LARGE_CONST_BYTES:
            findings.append(Finding(
                pass_name="hygiene", code="large-captured-constant",
                subject=f"{subject}:const{idx}",
                detail=(f"closure-captured constant #{idx}: "
                        f"{nbytes} bytes {getattr(const, 'dtype', '?')}"
                        f"{getattr(const, 'shape', ())} baked into the "
                        f"executable instead of passed as an argument")))

    for i, (expect, actual) in enumerate(zip(art.expect_donated,
                                             art.donated)):
        if expect and not actual:
            findings.append(Finding(
                pass_name="hygiene", code="undonated-cache-buffer",
                subject=f"{subject}:{art.invar_labels[i]}",
                detail=(f"{art.invar_labels[i]} is a step-consumed cache "
                        f"buffer but the lowered executable does not "
                        f"donate it — XLA copies it every dispatch")))
    return findings


@register_pass("hygiene")
def hygiene_pass(unit: AuditUnit) -> List[Finding]:
    findings: List[Finding] = []
    for art in unit.artifacts:
        findings.extend(_hygiene_artifact(unit, art))
    return findings
