"""Pass registry, findings, and the baseline allowlist policy.

A *pass* is a function ``(unit: AuditUnit) -> list[Finding]`` registered
under a stable name with :func:`register_pass`; :func:`run_passes` runs
every registered pass over every audit unit and returns the merged
findings.  Passes are pure over the unit's captured artifacts — they
never execute the computation they inspect.

Findings carry a stable ``key`` (``pass:code:subject``) that is the unit
of baseline accounting: :func:`diff_baseline` splits the error-severity
keys of a run against the checked-in allowlist into *new* findings
(regressions — fail CI) and *fixed* ones (baseline entries the run no
longer produces — also fail CI, because a fixed finding must shrink the
baseline in the same change that fixes it).  ``info`` findings are
reported but never gated.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "register_pass", "registered_passes", "run_passes",
           "load_baseline", "diff_baseline", "BASELINE_SCHEMA",
           "key_mesh_size", "key_in_scope"]

BASELINE_SCHEMA = "analysis-baseline-v1"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding with a baseline-stable identity.

    ``key`` (``pass:code:subject``) must be deterministic across runs on
    the same tree — subjects name the engine/artifact/leaf, never memory
    addresses or counters.  ``detail``/``provenance`` are for humans and
    stay out of the key so a reworded message does not churn the
    baseline.
    """

    pass_name: str
    code: str
    subject: str
    detail: str
    provenance: str = ""
    severity: str = "error"      # 'error' gates the baseline; 'info' reports

    @property
    def key(self) -> str:
        return f"{self.pass_name}:{self.code}:{self.subject}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key
        return d


_PASSES: Dict[str, Callable] = {}


def register_pass(name: str):
    """Decorator: register ``fn(unit) -> list[Finding]`` under ``name``."""
    def deco(fn):
        if name in _PASSES:
            raise ValueError(f"analysis pass {name!r} already registered")
        _PASSES[name] = fn
        return fn
    return deco


def registered_passes() -> Tuple[str, ...]:
    return tuple(_PASSES)


def run_passes(units: Sequence, only: Optional[Sequence[str]] = None
               ) -> List[Finding]:
    """Run registered passes over every audit unit, merging findings."""
    names = tuple(only) if only is not None else tuple(_PASSES)
    unknown = [n for n in names if n not in _PASSES]
    if unknown:
        raise ValueError(
            f"unknown analysis pass(es) {unknown}; "
            f"registered: {sorted(_PASSES)}")
    findings: List[Finding] = []
    for unit in units:
        for name in names:
            findings.extend(_PASSES[name](unit))
    return findings


# ------------------------------------------------------------------ baseline
def load_baseline(path) -> Dict[str, str]:
    """Load the allowlist as ``{finding key: note}``."""
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") != BASELINE_SCHEMA:
        raise ValueError(
            f"baseline {path}: schema {data.get('schema')!r} != "
            f"{BASELINE_SCHEMA!r}")
    out = {}
    for entry in data.get("findings", ()):
        out[entry["key"]] = entry.get("note", "")
    return out


#: mesh-parameterized keys end ``@mesh=N`` — the partition pass emits
#: one finding per audited mesh size, so a run that audited meshes
#: {2, 8} can neither confirm nor refute a ``@mesh=512`` entry
_MESH_SUFFIX_RE = re.compile(r"@mesh=(\d+)$")


def key_mesh_size(key: str) -> Optional[int]:
    """The mesh size a finding key is parameterized on (None if the
    key is mesh-independent)."""
    m = _MESH_SUFFIX_RE.search(key)
    return int(m.group(1)) if m else None


def key_in_scope(key: str, audited_meshes: Optional[Set[int]] = None,
                 unmeshed_in_scope: bool = True,
                 audited_archs: Optional[Sequence[str]] = None) -> bool:
    """Whether this run could have produced the finding behind ``key``.

    Only in-scope baseline entries can be declared stale: a
    ``@mesh=N`` entry is in scope iff mesh N was audited AND the
    finding's arch was in the partition matrix (subjects lead with
    ``<arch>/<mode>``; ``audited_archs=None`` means the full default
    matrix ran), and a mesh-independent entry iff the full
    (non-partition-only) audit ran.
    """
    mesh = key_mesh_size(key)
    if mesh is None:
        return unmeshed_in_scope
    if mesh not in (audited_meshes or ()):
        return False
    if audited_archs is None:
        return True
    subject = key.split(":", 2)[-1]
    return any(subject.startswith(f"{arch}/") for arch in audited_archs)


def diff_baseline(findings: Sequence[Finding], baseline: Dict[str, str],
                  audited_meshes: Optional[Set[int]] = None,
                  unmeshed_in_scope: bool = True,
                  audited_archs: Optional[Sequence[str]] = None
                  ) -> Tuple[List[Finding], List[str]]:
    """Split error findings against the allowlist.

    Returns ``(new, fixed)``: findings whose key is absent from the
    baseline (regressions), and *in-scope* baseline keys no run finding
    produced (stale entries that must be deleted alongside their fix).
    Either being non-empty fails the gate.  Baseline entries outside
    this run's scope (``@mesh=N`` for an unaudited N, an arch outside
    a ``--partition-archs`` restriction, or every mesh-independent key
    under ``--partition-only``) are left alone — a partial audit must
    not declare findings it never looked for to be fixed.
    """
    seen = {f.key for f in findings if f.severity == "error"}
    new = [f for f in findings
           if f.severity == "error" and f.key not in baseline]
    fixed = sorted(k for k in baseline if k not in seen
                   and key_in_scope(k, audited_meshes, unmeshed_in_scope,
                                    audited_archs))
    return new, fixed


def baseline_payload(findings: Sequence[Finding],
                     notes: Optional[Dict[str, str]] = None,
                     preserve: Optional[Dict[str, str]] = None) -> dict:
    """Serializable allowlist covering the given error findings.

    ``preserve`` carries existing entries outside the regenerating
    run's scope (unaudited mesh sizes) forward verbatim — rewriting the
    baseline at ``--mesh 2`` must not drop the ``@mesh=512`` family.
    """
    notes = notes or {}
    entries = dict(preserve or {})
    for f in findings:
        if f.severity == "error" and f.key not in entries:
            entries[f.key] = notes.get(f.key, "")
    return {"schema": BASELINE_SCHEMA,
            "findings": [{"key": k, "note": entries[k]}
                         for k in sorted(entries)]}
