"""Traffic auditor pass: jaxpr-derived bytes vs the analytic model.

Walks the decode step's ClosedJaxpr (:mod:`.jaxpr_walk`), bills any
cache outvar that did not arrive through an in-place chain as a fresh
full write, and compares the per-class byte buckets against
``TrafficModel.static_decode_classes`` at full occupancy (every slot
live at the layer cache length) — the operating point where telemetry's
occupancy-dependent accounting coincides with the structural count of
the lowered computation.  Any class mismatch is an error finding
(``traffic-drift``) that is never baselined: accounting drift between
``serve/telemetry.py`` and what XLA actually lowers fails statically.

``meta_*`` (block tables, length scalars) and ``param_*`` classes are
derived and reported but not gated: block-table indirection is O(pages)
int32 noise telemetry deliberately ignores, and param traffic depends
on dispatch decisions (MoE) the structural walk can't see.

Importing this module imports every ``repro.kernels.*.ops`` module so
their pallas cost handlers register; a pallas call without a handler
surfaces as a ``missing-cost-handler`` error finding.
"""
from __future__ import annotations

from typing import List

# importing the ops modules registers their pallas cost handlers
import repro.kernels.flash_attention.ops    # noqa: F401
import repro.kernels.paged_attention.ops    # noqa: F401
import repro.kernels.rate_match.ops         # noqa: F401
import repro.kernels.refresh_sim.ops        # noqa: F401
from repro.analysis.artifacts import AuditUnit
from repro.analysis.jaxpr_walk import CLASS_BY_LEAF, WRITE_BUCKET
from repro.analysis.registry import Finding, register_pass

__all__ = ["traffic_pass", "decode_traffic_report", "split_per_device"]

#: classes where the structural count must equal the analytic model
GATED_CLASSES = ("kv_sweep_read", "kv_page_read", "kv_append_write",
                 "state_read", "state_write",
                 "gather_view_read", "gather_view_write")

#: which cache leaf class backs each gated traffic class, per decode
#: cache layout — paged engines bill pools, contiguous the [B, L] cache
#: (gather-view traffic is derived from pool pages, so it splits with
#: the pool's factor)
_SPLIT_LEAF = {
    "contiguous": {"kv_sweep_read": "kv", "kv_page_read": "kv",
                   "kv_append_write": "kv", "gather_view_read": "kv",
                   "gather_view_write": "kv",
                   "state_read": "state", "state_write": "state"},
    "paged": {"kv_sweep_read": "kv_pool", "kv_page_read": "kv_pool",
              "kv_append_write": "kv_pool", "gather_view_read": "kv_pool",
              "gather_view_write": "kv_pool",
              "state_read": "state_pool", "state_write": "state_pool"},
}


def split_per_device(expected, leaf_factors, mode):
    """Split a global per-class decode bill by cache sharding factors.

    ``expected`` is ``TrafficModel.static_decode_classes`` output;
    ``leaf_factors`` maps cache leaf classes to their per-device split
    factor (``analysis.artifacts.sharded_leaf_factors``).  Returns
    ``(per_device, problems)``: per-device bytes for every gated class
    (exact integer division — a class whose global bytes the factor
    does not divide is a problem, because the 'per-device share' would
    be a fiction) plus any indivisibility problems found.
    """
    leaf_for = _SPLIT_LEAF["contiguous" if mode == "contiguous"
                           else "paged"]
    per_device = {}
    problems = []
    for cls in GATED_CLASSES:
        total = int(expected.get(cls, 0))
        if total == 0:
            per_device[cls] = 0
            continue
        factor = int(leaf_factors.get(leaf_for[cls], 1))
        if total % factor:
            problems.append(
                f"{cls}: global {total} bytes/step not divisible by the "
                f"{leaf_for[cls]!r} sharding factor {factor}")
        per_device[cls] = total // factor
    return per_device, problems


def decode_traffic_report(unit: AuditUnit) -> dict:
    """Derive the decode step's per-class bytes and the analytic twin.

    Returns ``{"derived": {...}, "expected": {...}, "match": bool}``
    (cached on ``unit.reports['traffic']``).
    """
    if "traffic" in unit.reports:
        return unit.reports["traffic"]
    art = unit.artifact("decode")
    res = art.walk()
    buckets = dict(res.buckets)
    # cache outvars that are NOT the same buffer as a cache invar are
    # fresh per-step writes (recurrent state, length high-water marks —
    # or a silently copied KV buffer, which the gate would then catch)
    outvars = art.closed_jaxpr.jaxpr.outvars
    taints = res.outvar_taints
    for var, taint, name in zip(outvars, taints, art.out_leaf_names):
        cls = CLASS_BY_LEAF.get(name)
        if cls is None:
            continue                       # logits etc: not cache state
        if taint is not None and taint.inplace:
            continue                       # billed at its scatter/dus
        buckets[WRITE_BUCKET[cls]] += (int(var.aval.size)
                                       * int(var.aval.dtype.itemsize))
    expected = unit.traffic.static_decode_classes(
        [unit.ctx] * unit.live, unit.mode)
    report = {
        "derived": buckets,
        "expected": expected,
        "problems": list(res.problems),
        "match": all(buckets.get(k, 0) == expected[k]
                     for k in GATED_CLASSES) and not res.problems,
    }
    unit.reports["traffic"] = report
    return report


@register_pass("traffic")
def traffic_pass(unit: AuditUnit) -> List[Finding]:
    findings: List[Finding] = []
    art = unit.artifact("decode")
    if art is None:
        return findings
    report = decode_traffic_report(unit)
    for problem in report["problems"]:
        code = ("missing-cost-handler"
                if problem.startswith("missing-cost-handler") else
                "walker-gap")
        findings.append(Finding(
            pass_name="traffic", code=code,
            subject=f"{unit.label}:decode",
            detail=problem))
    for k in GATED_CLASSES:
        got, want = report["derived"].get(k, 0), report["expected"][k]
        if got != want:
            findings.append(Finding(
                pass_name="traffic", code="traffic-drift",
                subject=f"{unit.label}:decode:{k}",
                detail=(f"jaxpr-derived {k} = {got} bytes/step but "
                        f"TrafficModel.static_decode_classes says {want} "
                        f"(live={unit.live}, ctx={unit.ctx}, "
                        f"mode={unit.mode})")))
    return findings
