"""Capture lowered serving artifacts into analyzable audit units.

An :class:`AuditUnit` is one engine configuration (arch x decode
backend x topology) with every lowered artifact the analyzer inspects:
the decode step, the top prefill bucket, and (contiguous engines) the
slot-insert executable.  Capture never executes anything — jaxprs come
from ``jitted.trace(...)`` and donation flags from
``jitted.lower(...).args_info``, both of which only need abstract
arguments, so units can be built from engines constructed with
``jax.eval_shape``'d params.

Each artifact's flattened invars are labeled from the argument pytree
paths (the same leaf names ``serve.engine.cache_specs`` switches on),
which seeds the taint walker and gives findings human-stable subjects.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.jaxpr_walk import CLASS_BY_LEAF, Taint, WalkResult, \
    walk_jaxpr
from repro.serve.telemetry import TrafficModel

__all__ = ["Artifact", "AuditUnit", "unit_from_engine", "leaf_name",
           "sharded_leaf_factors"]


def leaf_name(path) -> str:
    """Last named pytree key on a flatten path (''. when unnamed)."""
    for p in reversed(path):
        name = str(getattr(p, "name", getattr(p, "key", "")))
        if name:
            return name
    return ""


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


@dataclasses.dataclass
class Artifact:
    """One lowered executable, flattened for the passes."""

    name: str                                   # 'decode'|'prefill'|'insert'
    closed_jaxpr: object
    seeds: Tuple[Optional[Taint], ...]          # per flat invar
    invar_labels: Tuple[str, ...]               # per flat invar (path str)
    arg_specs: Tuple[object, ...]               # per flat invar: PartitionSpec|None
    donated: Tuple[bool, ...]                   # per flat invar (actual)
    expect_donated: Tuple[bool, ...]            # per flat invar (semantic)
    out_leaf_names: Tuple[str, ...]             # per flat outvar ('' if none)
    consts: Tuple[object, ...] = ()
    _walk: Optional[WalkResult] = None

    def walk(self) -> WalkResult:
        """Taint walk of the jaxpr (cached — traffic and sharding
        passes share one walk)."""
        if self._walk is None:
            self._walk = walk_jaxpr(self.closed_jaxpr, self.seeds)
        return self._walk


@dataclasses.dataclass
class AuditUnit:
    """One audited engine configuration."""

    label: str                   # '<arch>/<mode>/<topology>'
    cfg_name: str
    mode: str                    # 'contiguous' | 'gather' | 'pallas_paged'
    traffic: TrafficModel
    live: int                    # decode batch the step is lowered for
    ctx: int                     # logical context capacity (full occupancy)
    axis_sizes: Dict[str, int]
    data_axes: Tuple[str, ...]
    artifacts: List[Artifact]
    reports: Dict[str, dict] = dataclasses.field(default_factory=dict)

    def artifact(self, name: str) -> Optional[Artifact]:
        for a in self.artifacts:
            if a.name == name:
                return a
        return None


def sharded_leaf_factors(args, shardings, roles) -> Tuple[Dict[str, int],
                                                          List[str]]:
    """Per-device split factor for every cache leaf class of an
    artifact entry (``engine.lowered_artifacts()`` format).

    For each cache-role argument, walks the (abstract value, sharding)
    trees together and computes ``global_elements / shard_elements``
    per leaf via ``sharding.shard_shape`` — the factor the partition
    pass divides the global per-class byte bill by.  Returns
    ``(factors, problems)`` where factors maps the jaxpr-walk leaf
    class (``kv``/``kv_pool``/``state_pool``/``block``/...) to its
    factor; leaves of one class disagreeing on a factor is a problem
    (the bill would be ill-defined).
    """
    factors: Dict[str, int] = {}
    problems: List[str] = []
    for argnum, arg in enumerate(args):
        if roles.get(argnum) != "cache":
            continue
        sh = shardings[argnum] if shardings is not None else None
        if sh is None:
            continue
        leaves = jax.tree_util.tree_flatten_with_path(arg)[0]
        sh_leaves = jax.tree_util.tree_leaves(sh)
        for (path, leaf), s in zip(leaves, sh_leaves):
            cls = CLASS_BY_LEAF.get(leaf_name(path))
            if cls is None or not hasattr(s, "shard_shape"):
                continue
            shard = s.shard_shape(tuple(leaf.shape))
            n_shard = 1
            for d in shard:
                n_shard *= int(d)
            factor = max(1, int(leaf.size) // max(1, n_shard))
            prev = factors.setdefault(cls, factor)
            if prev != factor:
                problems.append(
                    f"leaf class {cls!r}: sharding factor {factor} at "
                    f"{_path_str(path)} disagrees with {prev} on an "
                    f"earlier leaf — per-class split is ill-defined")
    return factors, problems


def _seed_for(role: str, path, flat_index: int) -> Optional[Taint]:
    if role == "params":
        return Taint("param", resident=True, inplace=True, src=flat_index)
    if role == "cache":
        cls = CLASS_BY_LEAF.get(leaf_name(path))
        if cls is not None:
            return Taint(cls, resident=True, inplace=True, src=flat_index)
    return None


def _capture(entry: dict) -> Artifact:
    fn, args = entry["fn"], entry["args"]
    roles: Dict[int, str] = entry.get("roles", {})
    donate_expect = set(entry.get("expect_donate_argnums", ()))
    shardings = entry.get("shardings")

    closed = fn.trace(*args).jaxpr
    lowered = fn.lower(*args)
    donated = tuple(bool(info.donated)
                    for info in jax.tree_util.tree_leaves(lowered.args_info))

    seeds: List[Optional[Taint]] = []
    labels: List[str] = []
    specs: List[object] = []
    expect: List[bool] = []
    flat_index = 0
    for argnum, arg in enumerate(args):
        role = roles.get(argnum, "other")
        leaves = jax.tree_util.tree_flatten_with_path(arg)[0]
        sh = None if shardings is None else shardings[argnum]
        sh_leaves = (jax.tree_util.tree_leaves(sh)
                     if sh is not None else [None] * len(leaves))
        if len(sh_leaves) != len(leaves):
            raise ValueError(
                f"artifact {entry['name']}: arg {argnum} sharding tree has "
                f"{len(sh_leaves)} leaves for {len(leaves)} arg leaves")
        for (path, _), s in zip(leaves, sh_leaves):
            seeds.append(_seed_for(role, path, flat_index))
            labels.append(f"arg{argnum}{_path_str(path)}")
            specs.append(getattr(s, "spec", s))
            expect.append(argnum in donate_expect)
            flat_index += 1
    if len(seeds) != len(closed.jaxpr.invars):
        raise ValueError(
            f"artifact {entry['name']}: {len(seeds)} arg leaves vs "
            f"{len(closed.jaxpr.invars)} jaxpr invars — argument flattening "
            f"no longer matches the trace")

    out_shapes = jax.eval_shape(fn, *args)
    out_names = tuple(leaf_name(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(out_shapes)[0])
    return Artifact(
        name=entry["name"], closed_jaxpr=closed,
        seeds=tuple(seeds), invar_labels=tuple(labels),
        arg_specs=tuple(specs), donated=donated,
        expect_donated=tuple(expect), out_leaf_names=out_names,
        consts=tuple(closed.consts))


def unit_from_engine(engine, cfg_name: str,
                     topology: Optional[str] = None) -> AuditUnit:
    """Build the audit unit for a ``ServeEngine``.

    ``topology`` defaults to ``'solo'`` for a single-device mesh and
    ``'mesh<N>'`` otherwise — it is part of every finding subject, so a
    multi-device finding can be baselined without shadowing the solo
    configuration.
    """
    mesh = engine.mesh
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_dev = 1
    for s in axis_sizes.values():
        n_dev *= s
    if topology is None:
        topology = "solo" if n_dev == 1 else f"mesh{n_dev}"
    mode = "contiguous" if engine.paged is None else engine.decode_backend
    page = engine.paged.page_size if engine.paged is not None else 0
    traffic = TrafficModel.from_config(engine.model.cfg, engine.max_ctx,
                                       page_size=page)
    artifacts = [_capture(e) for e in engine.lowered_artifacts()]
    return AuditUnit(
        label=f"{cfg_name}/{mode}/{topology}", cfg_name=cfg_name, mode=mode,
        traffic=traffic, live=engine.max_batch, ctx=engine.max_ctx,
        axis_sizes=axis_sizes,
        data_axes=tuple(engine.policy.data_axes or ()),
        artifacts=artifacts)
