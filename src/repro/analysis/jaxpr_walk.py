"""Taint-propagating jaxpr walker: exact per-class HBM byte derivation.

The walker runs over a ClosedJaxpr with *taint seeds* on the top-level
invars (which flattened argument each invar is — a cache leaf, a param
leaf, or plain activation input) and derives, without executing
anything, how many bytes each *traffic class* moves per call.  The
rules mirror how XLA treats the equations:

* **Structural** ops (reshape/transpose/slice/broadcast/convert/
  sharding_constraint/...) are free and propagate taint: they describe
  the same buffer (or a fused view of it), and the *consumer* pays.
* A **compute** equation consuming a *resident* operand (a buffer that
  lives in HBM across steps: cache leaves, params, and the gather
  backend's materialized view) reads that operand's full aval once per
  use.  Compute outputs are fresh intermediates and carry no taint —
  this is what keeps e.g. attention scores from inheriting the KV
  sweep's residency and double-billing every downstream op.
* **gather** from a KV *pool* materializes a logical view: the output
  bytes are both read (from the pool) and written (the copy), and the
  result is a new *resident view* whose later consumption is the
  attention sweep.  Gathers from state pools / block tables / params
  are billed once at the gather and their outputs stay non-resident.
* **scatter / dynamic_update_slice** on a resident operand is an
  in-place append: it writes exactly the update operand's bytes, and
  the output continues the operand's identity (``inplace``), so the
  buffer is never billed as a fresh full-size write at the jaxpr
  boundary.
* **scan** multiplies its body's bytes by the trip count; cache leaves
  ride through as xs/ys slices keeping their taint.  Stacking the ys
  back is billed at zero — XLA aliases donated loop buffers in place,
  an assumption the donation hygiene lint guards.
* **pallas_call** is opaque: a registered per-kernel cost handler
  (:mod:`repro.analysis.costs`) supplies per-operand bytes, which are
  classified by operand taint.  A missing handler is itself reported.

Top-level *outvars* that are cache leaves but did **not** arrive
through an in-place chain are billed as full fresh writes — which is
exactly how a silently-copied cache would show up, so accounting drift
and copy regressions surface as cross-check failures rather than
passing unnoticed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.costs import lookup_pallas_cost

__all__ = ["Taint", "WalkResult", "PallasSite", "walk_jaxpr",
           "CLASS_BY_LEAF", "READ_BUCKET", "WRITE_BUCKET", "TRAFFIC_CLASSES"]

# flattened-leaf name -> taint class (mirrors serve.engine.cache_specs)
CLASS_BY_LEAF = {
    "k": "kv", "v": "kv",                  # contiguous KV buffers
    "kp": "kv_pool", "vp": "kv_pool",      # paged KV pools
    "conv": "state", "h": "state",         # contiguous recurrent state
    "conv_p": "state_pool", "h_p": "state_pool",
    "block": "block", "length": "length",  # paging metadata
}

# taint class -> bucket a *compute read* of a resident operand bills to
READ_BUCKET = {
    "kv": "kv_sweep_read", "kv_view": "kv_sweep_read",
    "kv_pool": "gather_view_read",     # direct pool read == view gather
    "state": "state_read", "state_pool": "state_read",
    "block": "meta_read", "length": "meta_read",
    "param": "param_read",
}

# taint class -> bucket a kernel's DMA of that operand bills to (pools
# read through a block-table index map move page granules, not a view)
KERNEL_READ_BUCKET = dict(READ_BUCKET, kv_pool="kv_page_read")

WRITE_BUCKET = {
    "kv": "kv_append_write", "kv_pool": "kv_append_write",
    "kv_view": "gather_view_write",
    "state": "state_write", "state_pool": "state_write",
    "block": "meta_write", "length": "meta_write",
    "param": "param_write",
}

TRAFFIC_CLASSES = (
    "kv_sweep_read", "kv_page_read", "kv_append_write",
    "state_read", "state_write",
    "gather_view_read", "gather_view_write",
    "meta_read", "meta_write", "param_read", "param_write",
)

_STRUCTURAL = frozenset({
    "reshape", "transpose", "squeeze", "expand_dims", "broadcast_in_dim",
    "convert_element_type", "slice", "rev", "copy", "reduce_precision",
    "sharding_constraint", "bitcast_convert_type",
})

_SCATTER = frozenset({"scatter", "scatter-add", "scatter-mul",
                      "scatter-min", "scatter-max"})

_HOST_SYNC = frozenset({"io_callback", "pure_callback", "debug_callback",
                        "callback", "infeed", "outfeed"})


@dataclasses.dataclass(frozen=True)
class Taint:
    """Provenance of one jaxpr var.

    ``resident``: the var names an HBM-resident buffer — compute reads
    of it are DRAM traffic.  ``inplace``: the var is the *same* buffer
    as a top-level input (structural / in-place-update chain), so
    emitting it as an output costs nothing.  ``src``: flat index of the
    top-level invar it descends from (sharding-lint provenance).
    """

    cls: str
    resident: bool = True
    inplace: bool = True
    src: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PallasSite:
    """One pallas_call encountered during the walk (for the sharding
    lint and for reporting): where it is, how often the enclosing loops
    run it, and what flows into each operand.  ``manual``: the call
    sits inside a ``shard_map`` region — its operands are already
    device-local shards, GSPMD never gathers or re-shards them, so the
    gspmd-gather sharding lint does not apply."""

    name_and_src: str
    multiplier: int
    operand_taints: Tuple[Optional[Taint], ...]
    operand_shapes: Tuple[Tuple[int, ...], ...]
    manual: bool = False


@dataclasses.dataclass
class WalkResult:
    buckets: Dict[str, int]
    pallas_sites: List[PallasSite]
    problems: List[str]          # non-fatal walker gaps (become findings)
    outvar_taints: Tuple[Optional[Taint], ...] = ()


def _aval_bytes(aval) -> int:
    return int(aval.size) * int(aval.dtype.itemsize)


def _is_literal(v) -> bool:
    return hasattr(v, "val")     # core.Literal carries .val; Var does not


class _Walker:
    def __init__(self):
        self.buckets: Dict[str, int] = {c: 0 for c in TRAFFIC_CLASSES}
        self.sites: List[PallasSite] = []
        self.problems: List[str] = []

    # -- env helpers -------------------------------------------------------
    @staticmethod
    def _get(env, v) -> Optional[Taint]:
        if _is_literal(v):
            return None
        return env.get(v)

    def _read(self, env, v, mult: int, table=READ_BUCKET) -> None:
        t = self._get(env, v)
        if t is not None and t.resident:
            self.buckets[table[t.cls]] += _aval_bytes(v.aval) * mult

    # -- recursion ---------------------------------------------------------
    def walk(self, jaxpr, env: Dict, mult: int) -> None:
        for eqn in jaxpr.eqns:
            self._eqn(eqn, env, mult)

    def _sub(self, closed, in_taints: Sequence[Optional[Taint]],
             env_out: Dict, outvars, mult: int) -> None:
        """Walk a ClosedJaxpr with the given invar taints; map the body
        outvar taints back onto ``outvars`` in ``env_out``."""
        inner = closed.jaxpr
        env: Dict = {}
        for var, t in zip(inner.invars, in_taints):
            if t is not None:
                env[var] = t
        self.walk(inner, env, mult)
        for outer, var in zip(outvars, inner.outvars):
            t = self._get(env, var)
            if t is not None:
                env_out[outer] = t

    # -- equation rules ----------------------------------------------------
    def _eqn(self, eqn, env: Dict, mult: int) -> None:
        prim = eqn.primitive.name

        if prim in _STRUCTURAL or prim == "dynamic_slice":
            # same buffer, different view: free, taint flows through.
            # dynamic_slice start operands are scalars; bill them only
            # if they are themselves resident metadata.
            for v in eqn.invars[1:]:
                self._read(env, v, mult)
            t = self._get(env, eqn.invars[0])
            if t is not None:
                env[eqn.outvars[0]] = t
            return

        if prim == "gather":
            self._gather(eqn, env, mult)
            return

        if prim in _SCATTER or prim == "dynamic_update_slice":
            self._scatter(eqn, env, mult)
            return

        if prim == "pallas_call":
            self._pallas(eqn, env, mult)
            return

        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "remat", "checkpoint"):
            closed = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
            if closed is None or not hasattr(closed, "jaxpr"):
                self.problems.append(f"{prim}: no recursable jaxpr param")
                return
            taints = [self._get(env, v) for v in eqn.invars]
            self._sub(closed, taints, env, eqn.outvars, mult)
            return

        if prim == "scan":
            self._scan(eqn, env, mult)
            return

        if prim == "shard_map":
            self._shard_map(eqn, env, mult)
            return

        if prim == "cond":
            self._cond(eqn, env, mult)
            return

        if prim == "while":
            self.problems.append(
                "while: unbounded trip count not statically billable")
            return

        if prim in _HOST_SYNC:
            # hygiene lint reports these; no byte accounting
            return

        # generic compute: resident operands are read, output is fresh
        for v in eqn.invars:
            self._read(env, v, mult)

    def _gather(self, eqn, env: Dict, mult: int) -> None:
        src, idx = eqn.invars[0], eqn.invars[1]
        out = eqn.outvars[0]
        self._read(env, idx, mult)           # resident block tables etc.
        t = self._get(env, src)
        if t is None or not t.resident:
            return
        nbytes = _aval_bytes(out.aval) * mult
        if t.cls == "kv_pool":
            # materialize the logical view: pool pages stream out AND
            # the contiguous copy is written; the view is then the
            # resident buffer attention sweeps.
            self.buckets["gather_view_read"] += nbytes
            self.buckets["gather_view_write"] += nbytes
            env[out] = Taint("kv_view", resident=True, inplace=False)
        else:
            # one-shot billed at the gather (state rows, page ids,
            # embedding rows); the small result is a fresh intermediate
            self.buckets[READ_BUCKET[t.cls]] += nbytes

    def _scatter(self, eqn, env: Dict, mult: int) -> None:
        operand = eqn.invars[0]
        if eqn.primitive.name == "dynamic_update_slice":
            update, indices = eqn.invars[1], eqn.invars[2:]
        else:
            indices, update = [eqn.invars[1]], eqn.invars[2]
        t = self._get(env, operand)
        if t is None or not t.resident:
            for v in eqn.invars:         # plain compute on intermediates
                self._read(env, v, mult)
            return
        for v in indices:
            self._read(env, v, mult)
        self._read(env, update, mult)    # a resident update is re-read
        self.buckets[WRITE_BUCKET[t.cls]] += _aval_bytes(update.aval) * mult
        env[eqn.outvars[0]] = t          # in-place chain continues

    def _pallas(self, eqn, env: Dict, mult: int) -> None:
        name_src = str(eqn.params.get("name_and_src_info", ""))
        taints = tuple(self._get(env, v) for v in eqn.invars)
        self.sites.append(PallasSite(
            name_and_src=name_src, multiplier=mult,
            operand_taints=taints,
            operand_shapes=tuple(tuple(v.aval.shape) for v in eqn.invars)))
        handler = lookup_pallas_cost(name_src)
        if handler is None:
            self.problems.append(f"missing-cost-handler:{name_src}")
            return
        cost = handler(eqn)
        for v, t, nbytes in zip(eqn.invars, taints, cost.reads):
            if t is not None and t.resident and nbytes:
                self.buckets[KERNEL_READ_BUCKET[t.cls]] += nbytes * mult
        aliases = dict(eqn.params.get("input_output_aliases", ()) or ())
        for out_idx, nbytes in enumerate(cost.writes):
            in_idx = next((i for i, o in aliases.items() if o == out_idx),
                          None)
            if in_idx is None:
                continue                 # fresh output: on-chip result
            t = taints[in_idx]
            if t is not None and t.resident and nbytes:
                self.buckets[WRITE_BUCKET[t.cls]] += nbytes * mult

    def _scan(self, eqn, env: Dict, mult: int) -> None:
        p = eqn.params
        ncon, ncar, length = p["num_consts"], p["num_carry"], p["length"]
        closed = p["jaxpr"]
        inner = closed.jaxpr
        body_env: Dict = {}
        for var, v in zip(inner.invars, eqn.invars):
            t = self._get(env, v)
            if t is not None:
                body_env[var] = t        # xs slices keep the stack's taint
        del ncon, ncar              # invar/outvar orders are already 1:1
        self.walk(inner, body_env, mult * int(length))
        # carries map through; ys keep the body outvar's taint — the
        # stack-back is free under the loop-aliasing assumption the
        # donation lint guards.
        for outer, var in zip(eqn.outvars, inner.outvars):
            t = self._get(body_env, var)
            if t is not None:
                env[outer] = t

    def _shard_map(self, eqn, env: Dict, mult: int) -> None:
        """Manual-mesh (shard_map) region: walk the body once on its
        per-shard avals and multiply by the shard count (mesh axes not
        in ``auto``), so per-shard bytes x shards == the exact global
        bill for evenly split operands — pools, block tables, tokens —
        which are the gated classes.  Replicated operands (params) bill
        their per-device copy x shards, the true all-device HBM figure
        (``param_*`` is derived-only, never gated).  Taints map through
        invars/outvars exactly like a pjit call, so pool in-place chains
        survive the region; pallas sites inside are flagged ``manual``
        for the sharding lint."""
        p = eqn.params
        inner = p["jaxpr"]               # an open Jaxpr, not a ClosedJaxpr
        auto = p.get("auto") or frozenset()
        shards = 1
        for name, size in dict(p["mesh"].shape).items():
            if name not in auto:
                shards *= int(size)
        body_env: Dict = {}
        for var, v in zip(inner.invars, eqn.invars):
            t = self._get(env, v)
            if t is not None:
                body_env[var] = t
        n0 = len(self.sites)
        self.walk(inner, body_env, mult * shards)
        for i in range(n0, len(self.sites)):
            self.sites[i] = dataclasses.replace(self.sites[i], manual=True)
        for outer, var in zip(eqn.outvars, inner.outvars):
            t = self._get(body_env, var)
            if t is not None:
                env[outer] = t

    def _cond(self, eqn, env: Dict, mult: int) -> None:
        branches = eqn.params["branches"]
        taints = [self._get(env, v) for v in eqn.invars[1:]]
        merged: Dict[str, int] = {}
        out_taints = None
        for br in branches:
            sub = _Walker()
            sub_env: Dict = {}
            sub._sub(br, taints, sub_env, eqn.outvars, 1)
            self.sites.extend(
                dataclasses.replace(s, multiplier=s.multiplier * mult)
                for s in sub.sites)
            self.problems.extend(sub.problems)
            for k, v in sub.buckets.items():
                merged[k] = max(merged.get(k, 0), v)
            br_out = tuple(sub_env.get(o) for o in eqn.outvars)
            out_taints = br_out if out_taints is None else tuple(
                a if a == b else None for a, b in zip(out_taints, br_out))
        for k, v in merged.items():
            self.buckets[k] += v * mult          # worst-case branch
        for o, t in zip(eqn.outvars, out_taints or ()):
            if t is not None:
                env[o] = t


def walk_jaxpr(closed_jaxpr, seeds: Sequence[Optional[Taint]]) -> WalkResult:
    """Walk a ClosedJaxpr with per-invar taint seeds.

    Returns per-class byte buckets for ONE call of the jaxpr, the
    pallas sites encountered, and any walker gaps.  Fresh (non-inplace)
    cache outvars are billed by the caller (:mod:`.traffic`), which
    knows the output pytree's leaf names.
    """
    w = _Walker()
    env: Dict = {}
    jaxpr = closed_jaxpr.jaxpr
    for var, t in zip(jaxpr.invars, seeds):
        if t is not None:
            env[var] = t
    w.walk(jaxpr, env, 1)
    # expose final env so traffic can bill fresh cache outvars
    res = WalkResult(buckets=w.buckets, pallas_sites=w.sites,
                     problems=w.problems)
    res.outvar_taints = tuple(w._get(env, v) for v in jaxpr.outvars)
    return res
