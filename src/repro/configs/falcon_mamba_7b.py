"""falcon-mamba-7b [ssm] — arXiv:2410.05355 (hf: tiiuae/falcon-mamba-7b)
[unverified tier].

64L d_model=4096 attention-free Mamba-1 blocks, ssm_state=16,
d_inner=8192 (expand 2), conv width 4, vocab=65024.  O(1) recurrent
state => long_500k runs.
"""
from repro.models.config import ModelConfig

ARCH = "falcon-mamba-7b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm",
        n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=65024,
        attn_pattern=("ssm",),
        ssm_state=16, ssm_conv=4, ssm_expand=2,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=256,
        attn_pattern=("ssm",),
        ssm_state=4, ssm_conv=4, ssm_expand=2,
        tie_embeddings=False, dtype="float32",
    )
