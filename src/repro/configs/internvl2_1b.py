"""internvl2-1b [vlm] — arXiv:2404.16821 (hf: OpenGVLab/InternVL2-1B).

Backbone only (per assignment): the Qwen2-0.5B language model —
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655, SwiGLU,
QKV bias.  The InternViT-300M frontend is a STUB: ``input_specs()``
feeds precomputed patch embeddings (repro.models.frontends).
"""
from repro.models.config import ModelConfig

ARCH = "internvl2-1b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151655, head_dim=64,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",), qkv_bias=True,
        tie_embeddings=True, frontend="vision", frontend_tokens=1025,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=32,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",), qkv_bias=True,
        tie_embeddings=True, frontend="vision", frontend_tokens=16,
        dtype="float32",
    )
