"""smollm-360m [dense] — hf: HuggingFaceTB/SmolLM-360M (llama arch).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, SwiGLU.
Note: 15 query heads do not divide a 16-way model axis — GSPMD pads
(baseline); the §Perf log studies the cost.
"""
from repro.models.config import ModelConfig

ARCH = "smollm-360m"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab_size=49152, head_dim=64,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",), tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
        d_ff=96, vocab_size=256, head_dim=20,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",), tie_embeddings=True,
        dtype="float32",
    )
