"""mixtral-8x22b [moe] — arXiv:2401.04088 (hf: mistralai/Mixtral-8x22B).

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts
top-2, SwiGLU, sliding-window attention (4096, per the assignment's
SWA note) — which makes every layer's KV cache bounded, so long_500k
runs with a windowed cache.
"""
from repro.models.config import ModelConfig

ARCH = "mixtral-8x22b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("local",), window_size=4096,
        n_experts=8, experts_per_token=2,
        # virtual split 2 -> 16 storage experts: exact layout transform
        # targeting the 16-way production model axis (see ModelConfig)
        moe_virtual_split=2,
        tie_embeddings=False, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("local",), window_size=8,
        n_experts=4, experts_per_token=2,
        tie_embeddings=False, dtype="float32",
    )
