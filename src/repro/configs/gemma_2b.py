"""gemma-2b [dense] — arXiv:2403.08295 (hf: google/gemma-2b).

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU,
head_dim=256, global attention, embeddings scaled by sqrt(d), tied head.
"""
from repro.models.config import ModelConfig

ARCH = "gemma-2b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab_size=256000, head_dim=256,
        mlp_gated=True, mlp_activation="gelu",
        attn_pattern=("global",),
        scale_embeddings=True, tie_embeddings=True,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=16,
        mlp_gated=True, mlp_activation="gelu",
        attn_pattern=("global",),
        scale_embeddings=True, tie_embeddings=True,
        dtype="float32",
    )
