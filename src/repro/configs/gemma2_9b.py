"""gemma2-9b [dense] — arXiv:2408.00118 (hf: google/gemma-2-9b).

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000, GeGLU,
head_dim=256, alternating local(4096)/global attention, attention-logit
softcap 50, final-logit softcap 30.

long_500k: runs — only the 21 global layers keep a full-length cache
(alternating-local halves it) and decode cost is linear per token.
"""
from repro.models.config import ModelConfig

ARCH = "gemma2-9b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        d_ff=14336, vocab_size=256000, head_dim=256,
        mlp_gated=True, mlp_activation="gelu",
        attn_pattern=("local", "global"), window_size=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        scale_embeddings=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        mlp_gated=True, mlp_activation="gelu",
        attn_pattern=("local", "global"), window_size=8,
        attn_softcap=50.0, logit_softcap=30.0,
        scale_embeddings=True, tie_embeddings=True,
        dtype="float32",
    )
