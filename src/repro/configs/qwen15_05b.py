"""qwen1.5-0.5b [dense] — hf: Qwen/Qwen1.5-0.5B.

24L d_model=1024 16H (MHA kv=16) d_ff=2816 vocab=151936, SwiGLU,
QKV bias, tied embeddings.
"""
from repro.models.config import ModelConfig

ARCH = "qwen1.5-0.5b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab_size=151936, head_dim=64,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",), qkv_bias=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",), qkv_bias=True,
        tie_embeddings=True, dtype="float32",
    )
