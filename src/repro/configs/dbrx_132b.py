"""dbrx-132b [moe] — hf: databricks/dbrx-base  [unverified tier].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, fine-grained
MoE 16 experts top-4, SwiGLU, global attention.
"""
from repro.models.config import ModelConfig

ARCH = "dbrx-132b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab_size=100352, head_dim=128,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",),
        n_experts=16, experts_per_token=4,
        tie_embeddings=False, rope_theta=5e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=256, head_dim=16,
        mlp_gated=True, mlp_activation="silu",
        attn_pattern=("global",),
        n_experts=8, experts_per_token=4,
        tie_embeddings=False, dtype="float32",
    )
