"""musicgen-medium [audio] — arXiv:2306.05284 (hf:
facebook/musicgen-medium).

Decoder backbone only (per assignment): 48L d_model=1536 24H (MHA
kv=24) d_ff=6144 vocab=2048 (EnCodec codebook size), plain GELU MLP
(non-gated), untied head.  The EnCodec/text-conditioning frontend is a
STUB providing precomputed frame embeddings.  (Published model uses
learned positional embeddings; we use RoPE — noted deviation, does not
change any shape or FLOP count at the precision the roofline uses.)
"""
from repro.models.config import ModelConfig

ARCH = "musicgen-medium"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab_size=2048, head_dim=64,
        mlp_gated=False, mlp_activation="gelu",
        attn_pattern=("global",),
        tie_embeddings=False, frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=64, head_dim=16,
        mlp_gated=False, mlp_activation="gelu",
        attn_pattern=("global",),
        tie_embeddings=False, frontend="audio", dtype="float32",
    )
