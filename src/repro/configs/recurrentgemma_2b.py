"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (hf:
google/recurrentgemma-2b).

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, GeGLU,
RG-LRU recurrent blocks with local attention every third layer
(published pattern: rec,rec,attn repeating; the final two layers are
recurrent), window 2048, head_dim=256, lru_width=2560.  Bounded state
=> long_500k runs.
"""
from repro.models.config import ModelConfig

ARCH = "recurrentgemma-2b"

def full_config() -> ModelConfig:
    # 26 layers = 8 x (rglru, rglru, local) + (rglru, rglru) tail —
    # the published schedule (attention every 3rd layer, recurrent end).
    return ModelConfig(
        name=ARCH, family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab_size=256000, head_dim=256,
        mlp_gated=True, mlp_activation="gelu",
        attn_pattern=("rglru", "rglru", "local"),
        pattern_tail=("rglru", "rglru"), window_size=2048,
        lru_width=2560, conv1d_width=4,
        scale_embeddings=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=16,
        mlp_gated=True, mlp_activation="gelu",
        attn_pattern=("rglru", "rglru", "local"),
        pattern_tail=("rglru", "rglru"), window_size=8,
        lru_width=64, conv1d_width=4,
        scale_embeddings=True, tie_embeddings=True,
        dtype="float32",
    )
