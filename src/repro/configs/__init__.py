"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture exposes ``full_config()`` (the exact
published configuration) and ``smoke_config()`` (a reduced same-family
config for CPU tests).
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

from repro.configs import (dbrx_132b, falcon_mamba_7b, gemma2_9b, gemma_2b,
                           internvl2_1b, mixtral_8x22b, musicgen_medium,
                           qwen15_05b, recurrentgemma_2b, smollm_360m)

_MODULES = {
    m.ARCH: m
    for m in (
        gemma_2b, smollm_360m, gemma2_9b, qwen15_05b, mixtral_8x22b,
        dbrx_132b, internvl2_1b, falcon_mamba_7b, recurrentgemma_2b,
        musicgen_medium,
    )
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.smoke_config() if smoke else mod.full_config()


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
