"""Checkpointing: atomic, content-verified, resharding-on-restore.

``store.py`` owns the whole design: checkpoints are written to a
temporary directory and atomically renamed (a crashed writer can never
leave a half-checkpoint that restore would read), every array records a
content hash verified on load, and restore re-shards onto whatever mesh
the restoring process is running — the saved layout does not constrain
the restored one, which is what lets :mod:`repro.train`'s trainer do
elastic re-mesh restarts.  Kept stdlib + numpy on the I/O path so a
checkpoint can be inspected without jax.
"""
