"""Checkpointing: atomic, content-verified, resharding-on-restore.

Layout:  <dir>/step_<n>/
            manifest.json   — step, tree structure, per-leaf path/shape/
                              dtype/crc32, framework versions
            arrays.npz      — flattened leaves keyed by tree path

Writes go to ``step_<n>.tmp`` and are renamed only after fsync —
a preempted/killed writer never corrupts the latest checkpoint, which
is what makes checkpoint/restart safe under node failure.  Restore
verifies every leaf's crc32 and ``device_put``s onto the *target*
sharding, so a checkpoint taken on one mesh restores onto another
(elastic re-scale path).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    arrays = _flatten(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            }
            for k, v in arrays.items()
        },
    }
    np.savez(os.path.join(tmp, _ARRAYS), **arrays)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings=None) -> Any:
    """Restore into the structure of ``like`` (shapes validated).

    ``shardings``: optional matching tree of NamedShardings — leaves are
    placed directly onto them (resharding across mesh changes).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, _ARRAYS))

    want = _flatten(like)
    for key, meta in manifest["leaves"].items():
        raw = data[key]
        crc = zlib.crc32(np.ascontiguousarray(raw).tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint corruption: crc mismatch for {key}")
    missing = set(want) - set(manifest["leaves"])
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")

    flat_like, tdef = jax.tree_util.tree_flatten(like)
    flat_sh = (
        tdef.flatten_up_to(shardings) if shardings is not None
        else [None] * len(flat_like)
    )
    keys = list(_flatten(like).keys())
    out = []
    for key, ref, sh in zip(keys, flat_like, flat_sh):
        arr = np.asarray(data[key])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return tdef.unflatten(out)
