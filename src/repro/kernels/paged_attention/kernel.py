"""Pallas TPU kernel: paged decode attention over block-table K/V pools.

The serving cache (:class:`repro.models.attention.PagedKVCache`) keeps
K/V rows in fixed-size pages of a shared pool, indirected per batch
slot through a block table.  The pure-JAX decode path resolves that
indirection by *materializing* the whole contiguous logical view every
step (``paged_kv_view``: a ``cache_len``-row gather per layer per
step) — exactly the avoidable off-chip traffic the RTC paper's
access-management argument targets.  This kernel consumes the block
table directly:

* ``grid = (batch, kv_heads, n_logical_pages)`` with the page axis
  innermost: TPU grids execute sequentially over the last dimension,
  so the online-softmax running state (max, sum, accumulator) lives in
  VMEM scratch across the pages of one (slot, kv_head) walk;
* the block table and per-slot positions ride in as **scalar
  prefetch** (:class:`~jax.experimental.pallas.tpu.PrefetchScalarGridSpec`):
  the K/V BlockSpec index maps read ``block[b, j]`` to DMA exactly one
  pool page HBM->VMEM per grid step — the gather never exists, pages
  stream through on-chip memory in block-table order;
* ring/append semantics, sliding windows, and softcap are enforced
  in-kernel from ``pos`` alone: logical slot ``s`` of page ``j`` holds
  absolute position ``pos - ((pos % cache_len - s) % cache_len)``
  (negative = never written), matching ``attention._cache_positions``;
  the partial tail page (``cache_len % page_size != 0``) masks its
  out-of-range rows the same way;
* pages with no valid row (unwritten ZERO pages, fully out-of-window
  pages) take a block-level early exit — no MXU cycles, mirroring the
  banded FLOP count of the jnp path;
* fp32 accumulation; one query token per slot (decode).

VMEM per step: q tile (g*hd*4) + K/V pages (2*page_size*hd*bytes) +
scores (g*page_size*4) + scratch (g*(hd+2)*4) — tiny next to the
flash-attention prefill tiles; the page size is the streaming quantum.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_attention"]

_NEG_INF = -1e30


def _kernel(block_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            page_size: int, cache_len: int, n_lp: int,
            window: Optional[int], softcap: Optional[float]):
    ib = pl.program_id(0)
    ij = pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Validity of this page's rows, from the slot position alone.
    # Logical slot ls holds absolute position pos - ((pos%L - ls) % L);
    # negative means never written (ZERO page reads land here), ls >=
    # cache_len is the partial tail page's padding.
    pos = pos_ref[ib]
    ls = ij * page_size + jax.lax.iota(jnp.int32, page_size)
    kv_pos = pos - ((pos % cache_len - ls) % cache_len)
    valid = (ls < cache_len) & (kv_pos >= 0)
    if window is not None:
        valid &= kv_pos > pos - window

    @pl.when(jnp.any(valid))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # [g, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # [page_size, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        hd = q.shape[-1]
        s = (q @ k.T) * (hd ** -0.5)                  # [g, page_size]
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid[None, :], s, _NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v

    @pl.when(ij == n_lp - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cache_len", "window", "softcap", "interpret"),
)
def paged_decode_attention(
    q: jnp.ndarray,        # [b, kv_heads, group, head_dim] post-RoPE query
    kp: jnp.ndarray,       # [n_pages, page_size, kv_heads, head_dim] pool
    vp: jnp.ndarray,
    block: jnp.ndarray,    # [b, n_logical_pages] int32 pool page ids
    pos: jnp.ndarray,      # [b] int32 absolute position being decoded
    *,
    cache_len: int,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """One-token GQA attention reading K/V pages in place.

    Returns [b, kv_heads, group, head_dim] — the same layout the gather
    path's grouped einsum produces before the head reshape.  Dead batch
    slots (block tables pointing at the DUMP page) return garbage rows
    exactly as the gather path does; the engine ignores them.
    """
    b, kvh, g, hd = q.shape
    n_lp = block.shape[1]
    page_size = kp.shape[1]
    if n_lp * page_size < cache_len:
        raise ValueError(
            f"block table covers {n_lp} pages x {page_size} rows "
            f"< cache_len {cache_len}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, n_lp),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda ib, ik, ij, blk, ps: (ib, ik, 0, 0)),
            # THE point of the kernel: the index map resolves the block
            # table, so each grid step DMAs exactly one pool page.
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda ib, ik, ij, blk, ps: (blk[ib, ij], 0, ik, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda ib, ik, ij, blk, ps: (blk[ib, ij], 0, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda ib, ik, ij, blk, ps: (ib, ik, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),        # running max
            pltpu.VMEM((g,), jnp.float32),        # running sum
            pltpu.VMEM((g, hd), jnp.float32),     # output accumulator
        ],
    )
    kern = functools.partial(
        _kernel, page_size=page_size, cache_len=cache_len, n_lp=n_lp,
        window=window, softcap=softcap)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(block, pos, q, kp, vp)
