"""Public op: paged decode attention with backend dispatch.

``paged_attention(..., backend="pallas")`` runs the block-table Pallas
kernel (interpret mode on CPU); ``backend="ref"`` runs the gather +
dense-softmax jnp oracle.  The model layer
(``repro.models.attention.attn_decode``) calls this op when the serving
engine selects ``decode_backend="pallas_paged"``; the oracle is the
parity anchor for the kernel test sweep.

Mesh locality: the kernel itself is mesh-oblivious — it indexes whatever
pool it is handed via the block table.  On multi-device meshes the
serving engine wraps the decode step in ``shard_map``
(:func:`repro.serve.engine.build_decode_step`): each device's program
receives its *local* pool extent plus the block-table rows of the slots
pinned to that shard, with global page ids rebased to local ones by
partition-id arithmetic before the call.  The kernel therefore never
causes a GSPMD gather, and :func:`_pallas_cost` — which prices a launch
from its operand avals — automatically bills the per-shard shapes that
the analysis walker multiplies by the shard count for the exact global
HBM figure.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.analysis.costs import KernelCost, register_pallas_cost
from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_ref

__all__ = ["paged_attention"]


def _pallas_cost(eqn) -> KernelCost:
    """HBM bytes of one kernel launch, from the equation's operand avals.

    Operand order is fixed by ``kernel.py``'s pallas_call: ``(block,
    pos, q, kp, vp)``.  The scalar-prefetch operands (block, pos) and q
    (index map depends only on outer grid axes) stream once; the K/V
    page blocks are driven by the *data-dependent* block-table index
    map, which the grid walks once per (batch, kv_head, logical_page) —
    every logical page's physical page is DMA'd whole, which is exactly
    ``TrafficModel.kv_page_read_bytes`` at full occupancy.  The output
    block is written once per (batch, kv_head).
    """
    block, pos, q, kp, vp = eqn.invars
    b, n_lp = block.aval.shape
    _, page, kvh, hd = kp.aval.shape
    page_read = b * kvh * n_lp * page * hd * int(kp.aval.dtype.itemsize)

    def nbytes(v):
        return int(v.aval.size) * int(v.aval.dtype.itemsize)

    return KernelCost(
        reads=(nbytes(block), nbytes(pos), nbytes(q), page_read, page_read),
        writes=tuple(nbytes(v) for v in eqn.outvars))


register_pallas_cost("kernels/paged_attention/", _pallas_cost)


def paged_attention(
    q: jnp.ndarray,        # [b, kv_heads, group, head_dim]
    kp: jnp.ndarray,       # [n_pages, page_size, kv_heads, head_dim]
    vp: jnp.ndarray,
    block: jnp.ndarray,    # [b, n_logical_pages] int32
    pos: jnp.ndarray,      # [b] int32
    *,
    cache_len: int,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    backend: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    if backend == "ref":
        return paged_decode_ref(q, kp, vp, block, pos, cache_len=cache_len,
                                window=window, softcap=softcap)
    if backend == "pallas":
        return paged_decode_attention(q, kp, vp, block, pos,
                                      cache_len=cache_len, window=window,
                                      softcap=softcap, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
