"""Public op: paged decode attention with backend dispatch.

``paged_attention(..., backend="pallas")`` runs the block-table Pallas
kernel (interpret mode on CPU); ``backend="ref"`` runs the gather +
dense-softmax jnp oracle.  The model layer
(``repro.models.attention.attn_decode``) calls this op when the serving
engine selects ``decode_backend="pallas_paged"``; the oracle is the
parity anchor for the kernel test sweep.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_ref

__all__ = ["paged_attention"]


def paged_attention(
    q: jnp.ndarray,        # [b, kv_heads, group, head_dim]
    kp: jnp.ndarray,       # [n_pages, page_size, kv_heads, head_dim]
    vp: jnp.ndarray,
    block: jnp.ndarray,    # [b, n_logical_pages] int32
    pos: jnp.ndarray,      # [b] int32
    *,
    cache_len: int,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    backend: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    if backend == "ref":
        return paged_decode_ref(q, kp, vp, block, pos, cache_len=cache_len,
                                window=window, softcap=softcap)
    if backend == "pallas":
        return paged_decode_attention(q, kp, vp, block, pos,
                                      cache_len=cache_len, window=window,
                                      softcap=softcap, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
