"""Pure-jnp oracle for the paged decode-attention kernel.

Reproduces the *gather* decode path of ``repro.models.attention`` —
resolve the block-table indirection into the contiguous
``[b, cache_len]`` logical view, then run the one-token grouped-query
attention math on it — with the full feature set the kernel supports:
per-slot absolute positions, ring/append cache semantics (a slot's
valid positions are derived from ``pos`` exactly as
``attention._cache_positions`` does), sliding-window masking, and
attention-logit softcapping.  fp32 softmax accumulation.

This is the bitwise mirror of what ``attn_decode`` computes on a paged
cache with ``backend="gather"``; the Pallas kernel is validated against
it with an interpret-mode accumulation-order tolerance (see
``tests/test_paged_attention_kernel.py``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["paged_decode_ref"]


def paged_decode_ref(
    q: jnp.ndarray,        # [b, kv_heads, group, head_dim] post-RoPE query
    kp: jnp.ndarray,       # [n_pages, page_size, kv_heads, head_dim] pool
    vp: jnp.ndarray,
    block: jnp.ndarray,    # [b, n_logical_pages] int32 pool page ids
    pos: jnp.ndarray,      # [b] int32 absolute position being decoded
    *,
    cache_len: int,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Gather + one-token GQA attention. Returns [b, kv_heads, group, hd]."""
    b, kvh, g, hd = q.shape
    n_lp = block.shape[1]
    page_size = kp.shape[1]
    k = kp[block].reshape((b, n_lp * page_size) + kp.shape[2:])[:, :cache_len]
    v = vp[block].reshape((b, n_lp * page_size) + vp.shape[2:])[:, :cache_len]

    # Absolute position held by each ring slot (-1 if never written):
    # slot s holds the newest p <= pos with p % cache_len == s.
    slots = jnp.arange(cache_len)
    kv_pos = pos[:, None] - ((pos[:, None] % cache_len - slots[None])
                             % cache_len)
    valid = kv_pos >= 0
    if window is not None:
        valid &= kv_pos > pos[:, None] - window

    scale = hd ** -0.5
    logits = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
