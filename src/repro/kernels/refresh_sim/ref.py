"""Pure-jnp oracle for the refresh-window row-state update.

One retention-window step of the RTC row-state machine, vectorized over
rows.  The Pallas kernel in ``kernel.py`` must match this bit-exactly
(tests sweep shapes/dtypes and ``assert_allclose`` against this).

Semantics (one window):
  * rows in the wrapped access interval [acc_start, acc_start+acc_len)
    within the allocated region [alloc_lo, alloc_hi) are *implicitly*
    replenished by demand transfers (RTT);
  * rows selected by the policy's explicit-refresh predicate are
    replenished by REF;
  * every other row ages by one window; an *allocated* row whose age
    exceeds the retention limit (1 window) is a data-integrity
    violation — the simulator asserts there are none for every
    non-oracle policy.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["window_update_masked_ref", "window_update_ref"]


def window_update_ref(
    age: jnp.ndarray,          # [n_rows] int32: windows since last replenish
    row_ids: jnp.ndarray,      # [n_rows] int32: absolute row indices
    acc_start: jnp.ndarray,    # scalar int32: stream cursor (absolute row)
    acc_len: jnp.ndarray,      # scalar int32: rows accessed this window
    alloc_lo: jnp.ndarray,     # scalar int32
    alloc_hi: jnp.ndarray,     # scalar int32 (exclusive)
    ref_lo: jnp.ndarray,       # scalar int32: explicit-refresh bound lo
    ref_hi: jnp.ndarray,       # scalar int32: explicit-refresh bound hi
    skip_accessed: jnp.ndarray,  # scalar bool: RTT skips rows accessed now
):
    """Returns (new_age, implicit, explicit, violation) — the last three
    are per-row int32 masks (summed by the caller)."""
    alloc_span = jnp.maximum(alloc_hi - alloc_lo, 1)
    # Access stream wraps within the allocated region.
    rel = row_ids - alloc_lo
    in_alloc = (row_ids >= alloc_lo) & (row_ids < alloc_hi)
    off = jnp.mod(rel - jnp.mod(acc_start - alloc_lo, alloc_span), alloc_span)
    accessed = in_alloc & (off < acc_len)

    in_ref_bound = (row_ids >= ref_lo) & (row_ids < ref_hi)
    explicit = in_ref_bound & jnp.where(skip_accessed, ~accessed, True)

    replenished = accessed | explicit
    new_age = jnp.where(replenished, 0, age + 1)
    violation = in_alloc & (new_age > 1)
    return (
        new_age.astype(age.dtype),
        accessed.astype(jnp.int32),
        explicit.astype(jnp.int32),
        violation.astype(jnp.int32),
    )


def window_update_masked_ref(
    age: jnp.ndarray,          # [n_rows] int32: windows since last replenish
    row_ids: jnp.ndarray,      # [n_rows] int32: absolute row indices
    touched: jnp.ndarray,      # [n_rows] bool/int: rows accessed this window
    alloc_lo: jnp.ndarray,     # scalar int32
    alloc_hi: jnp.ndarray,     # scalar int32 (exclusive)
    ref_lo: jnp.ndarray,       # scalar int32: explicit-refresh bound lo
    ref_hi: jnp.ndarray,       # scalar int32: explicit-refresh bound hi
    skip_accessed: jnp.ndarray,  # scalar bool: RTT skips rows accessed now
):
    """Trace-driven variant of :func:`window_update_ref`.

    Identical row-state machine, but the accessed set is an arbitrary
    per-row bitmap (one retention window of a measured page-access
    trace, via ``core.trace.window_masks``) instead of the affine
    cursor's wrapped interval.  Touches outside the allocation are
    ignored — a row with no live data replenishes nothing.
    """
    in_alloc = (row_ids >= alloc_lo) & (row_ids < alloc_hi)
    accessed = in_alloc & (touched != 0)

    in_ref_bound = (row_ids >= ref_lo) & (row_ids < ref_hi)
    explicit = in_ref_bound & jnp.where(skip_accessed, ~accessed, True)

    replenished = accessed | explicit
    new_age = jnp.where(replenished, 0, age + 1)
    violation = in_alloc & (new_age > 1)
    return (
        new_age.astype(age.dtype),
        accessed.astype(jnp.int32),
        explicit.astype(jnp.int32),
        violation.astype(jnp.int32),
    )
