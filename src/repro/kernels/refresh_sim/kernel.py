"""Pallas TPU kernel: refresh-window row-state update.

The RTC simulator advances millions of DRAM-row ages per retention
window; on TPU this is the hot inner loop of large-module, long-horizon
sweeps (Fig. 12 runs 4M-row modules over thousands of windows).  The
kernel tiles the row axis into VMEM blocks, computes the wrapped
access-interval membership *inside* the kernel (so only the 8 scalar
policy parameters travel to SMEM, not three O(n_rows) masks), fuses the
age update with the per-block implicit/explicit/violation reductions,
and writes one partial-count triple per grid step.

Block size 8×128 lanes (int32) keeps the working set at
3 * 4 KiB * BLOCK_ROWS/1024 << VMEM and the lane dimension
hardware-aligned (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["window_update_masked_pallas", "window_update_pallas",
           "BLOCK_ROWS"]

BLOCK_ROWS = 8 * 1024  # int32 rows per VMEM block: 32 KiB in, 32 KiB out


def _kernel(scalars_ref, age_ref, age_out_ref, counts_ref):
    """One row-block of the window update.

    scalars_ref: SMEM int32[8]:
      [acc_start, acc_len, alloc_lo, alloc_hi, ref_lo, ref_hi,
       skip_accessed, base_row_of_block0]
    age_ref / age_out_ref: VMEM int32[BLOCK]
    counts_ref: VMEM int32[3] per block: (implicit, explicit, violation)
    """
    blk = pl.program_id(0)
    acc_start = scalars_ref[0]
    acc_len = scalars_ref[1]
    alloc_lo = scalars_ref[2]
    alloc_hi = scalars_ref[3]
    ref_lo = scalars_ref[4]
    ref_hi = scalars_ref[5]
    skip_accessed = scalars_ref[6]
    base = scalars_ref[7]

    n = age_ref.shape[0]
    row_ids = base + blk * n + jax.lax.iota(jnp.int32, n)
    age = age_ref[...]

    alloc_span = jnp.maximum(alloc_hi - alloc_lo, 1)
    rel = row_ids - alloc_lo
    in_alloc = (row_ids >= alloc_lo) & (row_ids < alloc_hi)
    # Wrapped interval membership: distance from cursor, modulo region.
    off = jnp.mod(rel - jnp.mod(acc_start - alloc_lo, alloc_span), alloc_span)
    accessed = in_alloc & (off < acc_len)

    in_ref = (row_ids >= ref_lo) & (row_ids < ref_hi)
    explicit = in_ref & jnp.where(skip_accessed > 0, ~accessed, True)

    replenished = accessed | explicit
    new_age = jnp.where(replenished, 0, age + 1)
    violation = in_alloc & (new_age > 1)

    age_out_ref[...] = new_age
    counts_ref[0] = jnp.sum(accessed.astype(jnp.int32))
    counts_ref[1] = jnp.sum(explicit.astype(jnp.int32))
    counts_ref[2] = jnp.sum(violation.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def window_update_pallas(
    age: jnp.ndarray,
    acc_start,
    acc_len,
    alloc_lo,
    alloc_hi,
    ref_lo,
    ref_hi,
    skip_accessed,
    *,
    interpret: bool = True,
):
    """Tiled window update. Returns (new_age, implicit, explicit, violations).

    ``age`` length must be a multiple of BLOCK_ROWS (callers pad; padded
    rows sit outside [alloc_lo, alloc_hi) and [ref_lo, ref_hi) so they
    contribute nothing).
    """
    n = age.shape[0]
    if n % BLOCK_ROWS:
        raise ValueError(f"row count {n} not a multiple of {BLOCK_ROWS}")
    n_blocks = n // BLOCK_ROWS
    scalars = jnp.stack(
        [
            jnp.asarray(x, jnp.int32)
            for x in (acc_start, acc_len, alloc_lo, alloc_hi, ref_lo, ref_hi,
                      skip_accessed, 0)
        ]
    )
    new_age, counts = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # scalars broadcast to all blocks
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((3 * n_blocks,), jnp.int32),
        ],
        interpret=interpret,
    )(scalars, age.astype(jnp.int32))
    counts = counts.reshape(n_blocks, 3).sum(axis=0)
    return new_age, counts[0], counts[1], counts[2]


def _masked_kernel(scalars_ref, age_ref, touched_ref, age_out_ref,
                   counts_ref):
    """One row-block of the trace-driven window update.

    Same state machine as :func:`_kernel`, but the accessed set arrives
    as a per-row VMEM bitmap (one window of a measured trace) instead
    of being computed from the affine cursor scalars — so the scalar
    vector drops the cursor fields:

    scalars_ref: SMEM int32[8]:
      [alloc_lo, alloc_hi, ref_lo, ref_hi, skip_accessed,
       base_row_of_block0, 0, 0]  (padded to match the affine layout)
    age_ref / touched_ref / age_out_ref: VMEM int32[BLOCK]
    counts_ref: VMEM int32[3] per block: (implicit, explicit, violation)
    """
    blk = pl.program_id(0)
    alloc_lo = scalars_ref[0]
    alloc_hi = scalars_ref[1]
    ref_lo = scalars_ref[2]
    ref_hi = scalars_ref[3]
    skip_accessed = scalars_ref[4]
    base = scalars_ref[5]

    n = age_ref.shape[0]
    row_ids = base + blk * n + jax.lax.iota(jnp.int32, n)
    age = age_ref[...]

    in_alloc = (row_ids >= alloc_lo) & (row_ids < alloc_hi)
    accessed = in_alloc & (touched_ref[...] != 0)

    in_ref = (row_ids >= ref_lo) & (row_ids < ref_hi)
    explicit = in_ref & jnp.where(skip_accessed > 0, ~accessed, True)

    replenished = accessed | explicit
    new_age = jnp.where(replenished, 0, age + 1)
    violation = in_alloc & (new_age > 1)

    age_out_ref[...] = new_age
    counts_ref[0] = jnp.sum(accessed.astype(jnp.int32))
    counts_ref[1] = jnp.sum(explicit.astype(jnp.int32))
    counts_ref[2] = jnp.sum(violation.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def window_update_masked_pallas(
    age: jnp.ndarray,
    touched: jnp.ndarray,
    alloc_lo,
    alloc_hi,
    ref_lo,
    ref_hi,
    skip_accessed,
    *,
    interpret: bool = True,
):
    """Tiled trace-driven window update.

    Returns (new_age, implicit, explicit, violations).  ``age`` and
    ``touched`` lengths must be an equal multiple of BLOCK_ROWS
    (callers pad; padded rows are untouched and outside every bound).
    """
    n = age.shape[0]
    if n % BLOCK_ROWS:
        raise ValueError(f"row count {n} not a multiple of {BLOCK_ROWS}")
    if touched.shape != age.shape:
        raise ValueError(
            f"touched shape {touched.shape} != age shape {age.shape}")
    n_blocks = n // BLOCK_ROWS
    scalars = jnp.stack(
        [
            jnp.asarray(x, jnp.int32)
            for x in (alloc_lo, alloc_hi, ref_lo, ref_hi, skip_accessed,
                      0, 0, 0)
        ]
    )
    new_age, counts = pl.pallas_call(
        _masked_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # scalars broadcast to all blocks
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((3 * n_blocks,), jnp.int32),
        ],
        interpret=interpret,
    )(scalars, age.astype(jnp.int32), touched.astype(jnp.int32))
    counts = counts.reshape(n_blocks, 3).sum(axis=0)
    return new_age, counts[0], counts[1], counts[2]
