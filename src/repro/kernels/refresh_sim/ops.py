"""Public op: refresh-window row-state update with backend dispatch.

``window_update(..., backend=)`` — affine-cursor access model;
``window_update_masked(..., backend=)`` — trace-driven bitmap model:
  * ``"pallas"`` — the tiled TPU kernel (interpret=True on CPU);
  * ``"ref"``    — the pure-jnp oracle (always available, used for
    allclose validation and as the fast path under jit on CPU).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.analysis.costs import register_pallas_cost, uniform_cost
from repro.kernels.refresh_sim.kernel import (
    BLOCK_ROWS, window_update_masked_pallas, window_update_pallas)
from repro.kernels.refresh_sim.ref import (
    window_update_masked_ref, window_update_ref)

__all__ = ["window_update", "window_update_masked", "BLOCK_ROWS"]

# row-tiled single sweep: age rows in, age rows + per-block counts out,
# every block touched exactly once — the uniform cost model is exact
register_pallas_cost("kernels/refresh_sim/", uniform_cost)


def window_update(
    age: jnp.ndarray,
    acc_start,
    acc_len,
    alloc_lo,
    alloc_hi,
    ref_lo,
    ref_hi,
    skip_accessed,
    *,
    backend: str = "ref",
    interpret: bool = True,
):
    """Returns (new_age, n_implicit, n_explicit, n_violations)."""
    if backend == "pallas":
        n = age.shape[0]
        pad = (-n) % BLOCK_ROWS
        if pad:
            # Padded rows live past every bound: inert.
            age_p = jnp.concatenate([age, jnp.zeros((pad,), age.dtype)])
        else:
            age_p = age
        new_age, imp, exp, vio = window_update_pallas(
            age_p, acc_start, acc_len, alloc_lo, alloc_hi, ref_lo, ref_hi,
            skip_accessed, interpret=interpret,
        )
        return new_age[:n], imp, exp, vio
    if backend == "ref":
        row_ids = jnp.arange(age.shape[0], dtype=jnp.int32)
        new_age, imp, exp, vio = window_update_ref(
            age, row_ids,
            jnp.asarray(acc_start, jnp.int32), jnp.asarray(acc_len, jnp.int32),
            jnp.asarray(alloc_lo, jnp.int32), jnp.asarray(alloc_hi, jnp.int32),
            jnp.asarray(ref_lo, jnp.int32), jnp.asarray(ref_hi, jnp.int32),
            jnp.asarray(skip_accessed, bool),
        )
        return new_age, imp.sum(), exp.sum(), vio.sum()
    raise ValueError(f"unknown backend {backend!r}")


def window_update_masked(
    age: jnp.ndarray,
    touched: jnp.ndarray,
    alloc_lo,
    alloc_hi,
    ref_lo,
    ref_hi,
    skip_accessed,
    *,
    backend: str = "ref",
    interpret: bool = True,
):
    """Trace-driven window update (accessed set = per-row bitmap).

    Returns (new_age, n_implicit, n_explicit, n_violations).
    """
    if touched.shape != age.shape:
        raise ValueError(
            f"touched shape {touched.shape} != age shape {age.shape}")
    if backend == "pallas":
        n = age.shape[0]
        pad = (-n) % BLOCK_ROWS
        if pad:
            # Padded rows live past every bound and are untouched: inert.
            age_p = jnp.concatenate([age, jnp.zeros((pad,), age.dtype)])
            touched_p = jnp.concatenate(
                [touched, jnp.zeros((pad,), touched.dtype)])
        else:
            age_p, touched_p = age, touched
        new_age, imp, exp, vio = window_update_masked_pallas(
            age_p, touched_p, alloc_lo, alloc_hi, ref_lo, ref_hi,
            skip_accessed, interpret=interpret,
        )
        return new_age[:n], imp, exp, vio
    if backend == "ref":
        row_ids = jnp.arange(age.shape[0], dtype=jnp.int32)
        new_age, imp, exp, vio = window_update_masked_ref(
            age, row_ids, touched,
            jnp.asarray(alloc_lo, jnp.int32), jnp.asarray(alloc_hi, jnp.int32),
            jnp.asarray(ref_lo, jnp.int32), jnp.asarray(ref_hi, jnp.int32),
            jnp.asarray(skip_accessed, bool),
        )
        return new_age, imp.sum(), exp.sum(), vio.sum()
    raise ValueError(f"unknown backend {backend!r}")
