"""Pallas TPU kernels for the reproduction's compute hot-spots.

Each kernel is a package of three modules — ``kernel.py`` (the Pallas
TPU implementation, runnable in interpret mode on CPU so CI validates
it without hardware), ``ref.py`` (a pure-jnp oracle with the same
feature set), and ``ops.py`` (the public op with ``backend="pallas" |
"ref"`` dispatch).  The kernel CI job runs every package's parity suite
in interpret mode.

Packages
--------
``flash_attention``
    Tiled online-softmax attention for training/prefill (GQA, causal,
    sliding-window, softcap).  Sequences that don't tile are padded to
    the block grid and sliced back (padded keys sit past every real
    query causally; padded query rows are discarded).
``rate_match``
    Algorithm-1 transfer-schedule bits.
``refresh_sim``
    Retention-window age update of the refresh simulator.
``paged_attention``
    Decode attention that consumes the serving cache's block tables
    *directly* — the RTC argument applied to the serving hot path.

Paged-attention design note (PR 5)
----------------------------------
The paged serving cache (:class:`repro.models.attention.PagedKVCache`)
stores K/V rows in fixed-size pages of a shared pool behind a per-slot
block table.  The pure-JAX decode path materializes the contiguous
logical view every step (``paged_kv_view``: a ``cache_len``-row gather
per attention layer), which is precisely the predictable-but-wasted
memory traffic the paper's refresh-triggered access management
eliminates — the data already sits in DRAM in a layout an address
generator can walk, so copying it into a contiguous staging buffer
buys nothing.

The kernel removes the copy:

* **Grid layout** — ``(batch_slot, kv_head, logical_page)`` with the
  page axis innermost.  TPU grids are sequential over the last
  dimension, so the online-softmax state (running max, running sum,
  fp32 output accumulator) lives in VMEM scratch across one slot+head's
  page walk, exactly like the flash kernel's KV-block axis.
* **Block-table index map** — the block table and per-slot positions
  are scalar-prefetch operands
  (:class:`~jax.experimental.pallas.tpu.PrefetchScalarGridSpec`); the
  K/V BlockSpec index maps evaluate ``block[b, j]`` so the pipeline
  DMAs exactly one pool page HBM->VMEM per grid step, in block-table
  order.  Ring/append validity, sliding windows, softcap, and the
  partial tail page are reconstructed in-kernel from ``pos`` alone
  (matching ``attention._cache_positions``), and pages with no valid
  row take a block-level early exit.
* **Why no gather** — the gather costs a full logical-view read+write
  per layer per step regardless of context occupancy and defeats the
  energy model's point (telemetry now accounts that phantom traffic on
  the gather path and only true per-page reads on the kernel path).
  The kernel's traffic is ``ceil(ctx/page_size)`` pages per layer —
  the minimum the block-table indirection permits.

Engine-side selection: ``ServeEngine(decode_backend="pallas_paged")``
(default ``"gather"``); generations are identical across backends on
all 10 archs (interpret-mode parity is accumulation-order tolerant on
logits, bit-exact on sampled tokens — pinned in
``tests/test_paged_attention_kernel.py``).

Device-local decode under ``shard_map`` (PR 8)
----------------------------------------------
On a mesh, GSPMD cannot see through the block-table indirection: any
page of the shared pool might serve any slot, so partitioning the
unmapped kernel forces all-gathers of the *whole pool* every step —
the ``pool-collective`` finding family the static auditor used to
baseline.  The fix is layout, not kernel code: the kernel itself stays
mesh-oblivious (one slot+head's page walk never crosses a slot
boundary), and the serving layer makes locality true by construction.
:class:`~repro.serve.paging.PageTable` pins slots to data-axis shards
and carves the pool into per-shard extents (``shards`` contiguous
ranges of pages, each with its own free list and reserved zero/dump
pages), so a slot's block table only ever names pages in its own
shard's extent.  ``ServeEngine`` then wraps the decode step in
:func:`jax.shard_map` with the pool, block tables, and slot axes
sharded over ``data``: each device runs the unchanged kernel over its
local pool extent (block ids rebased by the shard's page offset
in-body), and the only cross-device traffic left is the per-step token
exchange.  Generations are bit-identical to the solo engine — pinned
across forced preemption/offload in ``tests/test_serve_multidevice.py``
— and the auditor's partition gate now runs against an *empty*
baseline at every mesh size.
"""
