"""Pure-jnp oracle for the flash-attention kernel.

Straightforward O(s^2) softmax attention with the full feature set the
kernel supports: GQA (kv heads broadcast over query groups), causal
masking, sliding-window masking, and Gemma-2-style attention-logit
softcapping.  fp32 softmax accumulation regardless of input dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["mha_ref"]


def mha_ref(
    q: jnp.ndarray,           # [b, sq, h, hd]
    k: jnp.ndarray,           # [b, skv, kvh, hd]
    v: jnp.ndarray,           # [b, skv, kvh, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,        # absolute position of q[0] (decode/chunked)
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows -> zero output
    out = jnp.einsum("bhqs,bshd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
