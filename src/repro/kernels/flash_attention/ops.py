"""Public op: flash attention with backend dispatch.

``attention(..., backend="pallas")`` runs the tiled TPU kernel
(interpret mode on CPU); ``backend="ref"`` runs the O(s^2) jnp oracle.
The model layer (repro.models.attention) uses its own blocked-jnp path
for XLA lowering; on real TPU hardware this op substitutes via
``use_kernel=True`` plumbing in the serving/training launchers.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.analysis.costs import KernelCost, register_pallas_cost
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref

__all__ = ["attention"]


def _pallas_cost(eqn) -> KernelCost:
    """HBM bytes of one flash launch (operands ``(q, k, v)``).

    Q tiles and the output stream once (their index maps ignore the
    inner kv axis); K/V tiles are re-DMA'd for every (head, q-block)
    pair the grid sweeps — ``n_heads/n_kv_heads * n_q_blocks`` full
    passes over the KV sequence, read from the grid in the equation's
    ``grid_mapping`` so the count tracks the kernel's actual tiling.
    """
    q, k, v = eqn.invars
    grid = tuple(eqn.params["grid_mapping"].grid)   # (b, h, n_q, n_kv)
    n_q = int(grid[2])
    h = q.aval.shape[2]
    kvh = k.aval.shape[2]

    def nbytes(var):
        return int(var.aval.size) * int(var.aval.dtype.itemsize)

    kv_sweeps = (h // kvh) * n_q
    return KernelCost(
        reads=(nbytes(q), nbytes(k) * kv_sweeps, nbytes(v) * kv_sweeps),
        writes=tuple(nbytes(o) for o in eqn.outvars))


register_pallas_cost("kernels/flash_attention/", _pallas_cost)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    backend: str = "ref",
    interpret: bool = True,
) -> jnp.ndarray:
    if backend == "ref":
        return mha_ref(q, k, v, causal=causal, window=window,
                       softcap=softcap)
    if backend == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
