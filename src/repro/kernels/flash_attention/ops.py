"""Public op: flash attention with backend dispatch.

``attention(..., backend="pallas")`` runs the tiled TPU kernel
(interpret mode on CPU); ``backend="ref"`` runs the O(s^2) jnp oracle.
The model layer (repro.models.attention) uses its own blocked-jnp path
for XLA lowering; on real TPU hardware this op substitutes via
``use_kernel=True`` plumbing in the serving/training launchers.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref

__all__ = ["attention"]


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    backend: str = "ref",
    interpret: bool = True,
) -> jnp.ndarray:
    if backend == "ref":
        return mha_ref(q, k, v, causal=causal, window=window,
                       softcap=softcap)
    if backend == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
