"""Pallas TPU kernel: flash attention (GQA, causal, SWA, softcap).

TPU-native tiling of the FlashAttention recurrence:

* ``grid = (batch, q_heads, q_blocks, kv_blocks)`` with the KV axis
  innermost: TPU grids execute sequentially over the last dimension, so
  the online-softmax running state (max, sum, accumulator) lives in
  VMEM scratch across KV steps of one (b, h, q_block) tile;
* BlockSpecs stream one MXU-aligned K/V tile per step HBM->VMEM
  (``kv_block x head_dim``), the GQA group mapping ``ih -> ih // group``
  reading each KV head once per query head in its group;
* causal + sliding-window masks use *block-level early exit*
  (``pl.when`` over the block index) so fully-masked tiles spend no
  MXU cycles — matching the banded FLOP count of the jnp reference;
* sequences that don't tile are padded to the block grid and sliced
  back (padded keys masked in-kernel via ``kv_len``; padded query rows
  discarded), so any (seq, q_block, kv_block) combination lowers;
* fp32 accumulation, bf16/f32 inputs.

VMEM per step: q tile (q_blk*hd*4) + K/V tiles (2*kv_blk*hd*2) +
scores (q_blk*kv_blk*4) + scratch (q_blk*(hd+2)*4) — ~0.8 MiB at the
default 128x512x256 tiling, comfortably inside 16 MiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention", "DEFAULT_Q_BLOCK", "DEFAULT_KV_BLOCK"]

DEFAULT_Q_BLOCK = 128
DEFAULT_KV_BLOCK = 512
_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            q_block: int, kv_block: int, n_kv_blocks: int, causal: bool,
            window: Optional[int], softcap: Optional[float],
            kv_len: Optional[int]):
    qb = pl.program_id(2)
    kvb = pl.program_id(3)

    @pl.when(kvb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qb * q_block
    kv_start = kvb * kv_block

    # ---- block-level early exit -------------------------------------------
    live = jnp.asarray(True)
    if causal:
        live &= kv_start <= q_start + q_block - 1
    if window is not None:
        live &= kv_start + kv_block > q_start - window + 1
    if kv_len is not None:
        live &= kv_start < kv_len        # block entirely in tile padding

    @pl.when(live)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        hd = q.shape[-1]
        s = (q @ k.T) * (hd ** -0.5)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qi = q_start + jax.lax.iota(jnp.int32, q_block)[:, None]
        kj = kv_start + jax.lax.iota(jnp.int32, kv_block)[None, :]
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= kj > qi - window
        if kv_len is not None:
            mask &= kj < kv_len          # keys in the tile padding
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v

    @pl.when(kvb == n_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe_l[:, None]).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_block", "kv_block",
                     "interpret"),
)
def flash_attention(
    q: jnp.ndarray,            # [b, sq, h, hd]
    k: jnp.ndarray,            # [b, skv, kvh, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    if h % kvh:
        raise ValueError("n_heads must be a multiple of n_kv_heads")
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    # Sequences that don't tile are padded up to the block grid and the
    # result sliced back: padded KEYS are masked in-kernel (``kv_len``
    # bounds ``kj`` — causality alone would leave them visible to the
    # padded query rows, and non-causal calls to everyone); padded QUERY
    # rows compute garbage that the final slice discards.
    pad_q = (-sq) % q_block
    pad_kv = (-skv) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    group = h // kvh
    n_kv = skv_p // kv_block

    kern = functools.partial(
        _kernel, q_block=q_block, kv_block=kv_block, n_kv_blocks=n_kv,
        causal=causal, window=window, softcap=softcap,
        kv_len=skv if pad_kv else None)

    out = pl.pallas_call(
        kern,
        grid=(b, h, sq_p // q_block, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_block, 1, hd),
                         lambda ib, ih, iq, ikv: (ib, iq, ih, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda ib, ih, iq, ikv, g=group: (ib, ikv, ih // g, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda ib, ih, iq, ikv, g=group: (ib, ikv, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, hd),
                               lambda ib, ih, iq, ikv: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),      # running max
            pltpu.VMEM((q_block,), jnp.float32),      # running sum
            pltpu.VMEM((q_block, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq] if pad_q else out
