"""Pure-jnp oracle for the Algorithm-1 schedule generator.

Generates the xfer bit for a contiguous range of (1-indexed) slots via
the closed form derived in :mod:`repro.core.rate_matching`:

    xfer_i = ceil(i*na/nr) - ceil((i-1)*na/nr)

with (na, nr) the gcd-reduced rates.  Division-free formulation used by
both backends: ceil(k*na/nr) = (k*na + nr - 1) // nr.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["schedule_block_ref"]


def schedule_block_ref(start: jnp.ndarray, length: int, na: int, nr: int):
    """xfer bits for slots [start+1, start+length] (int32, 0/1)."""
    i = jnp.asarray(start, jnp.int32) + 1 + jnp.arange(length, dtype=jnp.int32)
    if nr <= na:
        return jnp.ones((length,), jnp.int32)
    cur = (i * na + (nr - 1)) // nr
    prev = ((i - 1) * na + (nr - 1)) // nr
    return (cur - prev).astype(jnp.int32)
