"""Public op: Algorithm-1 schedule bits with backend dispatch."""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.analysis.costs import register_pallas_cost, uniform_cost
from repro.kernels.rate_match.kernel import BLOCK_SLOTS, schedule_pallas
from repro.kernels.rate_match.ref import schedule_block_ref

__all__ = ["schedule_bits", "BLOCK_SLOTS"]

# single-sweep grid: the scalar rate operands stream once, each output
# block is produced once — the uniform cost model is exact
register_pallas_cost("kernels/rate_match/", uniform_cost)


def schedule_bits(
    n_a: int, n_r: int, length: int, *, start: int = 0,
    backend: str = "ref", interpret: bool = True,
):
    """xfer bits for slots [start+1, start+length] (int32 0/1 array).

    Rates are gcd-reduced first so the int32 products ``i * na`` stay
    far from overflow for any module geometry we model.
    """
    g = math.gcd(n_a, n_r) if n_a > 0 else max(n_r, 1)
    na, nr = n_a // g, max(1, n_r // g)
    # Slot index within the repeating period keeps i*na bounded.
    start = start % nr if nr else 0
    if backend == "ref":
        return schedule_block_ref(start, length, na, nr)
    if backend == "pallas":
        pad = (-length) % BLOCK_SLOTS
        bits = schedule_pallas(start, na, nr, length=length + pad, interpret=interpret)
        return bits[:length]
    raise ValueError(f"unknown backend {backend!r}")
