"""Pallas TPU kernel: bulk Algorithm-1 schedule generation.

Full-RTC's rate FSM emits one xfer bit per refresh slot; sweeping a
4M-row module over many retention windows means generating O(10^8)
schedule bits when replaying traces.  The closed form is embarrassingly
parallel, so the kernel materializes bits in VMEM-sized blocks from
nothing but three SMEM scalars (start, na, nr) — zero HBM input
bandwidth, output-bound by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["schedule_pallas", "BLOCK_SLOTS"]

BLOCK_SLOTS = 16 * 1024  # 64 KiB int32 out per block


def _kernel(scalars_ref, out_ref):
    blk = pl.program_id(0)
    start = scalars_ref[0]
    na = scalars_ref[1]
    nr = scalars_ref[2]
    n = out_ref.shape[0]
    i = start + blk * n + 1 + jax.lax.iota(jnp.int32, n)
    cur = (i * na + (nr - 1)) // nr
    prev = ((i - 1) * na + (nr - 1)) // nr
    bits = (cur - prev).astype(jnp.int32)
    out_ref[...] = jnp.where(nr <= na, jnp.ones_like(bits), bits)


@functools.partial(jax.jit, static_argnames=("length", "interpret"))
def schedule_pallas(start, na, nr, *, length: int, interpret: bool = True):
    """xfer bits for slots [start+1, start+length]; length % BLOCK == 0."""
    if length % BLOCK_SLOTS:
        raise ValueError(f"length {length} not a multiple of {BLOCK_SLOTS}")
    scalars = jnp.stack([jnp.asarray(x, jnp.int32) for x in (start, na, nr)])
    return pl.pallas_call(
        _kernel,
        grid=(length // BLOCK_SLOTS,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((BLOCK_SLOTS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((length,), jnp.int32),
        interpret=interpret,
    )(scalars)
