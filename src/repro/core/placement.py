"""DRAM data-mapping policies: cache pages -> module banks/rows.

The paper treats the DRAM row as the refresh granule; *which* rows a
workload touches therefore depends on how its data is mapped onto the
module — a policy axis the paper leaves to the memory controller and
that PENDRAM/DRMap (PAPERS.md) make explicit.  This module is that
policy layer for the serving stack: a :class:`Placement` assigns every
physical page of every :class:`repro.serve.paging.PageTable` pool
stream (plus the resident weight region) a row interval on a
:class:`repro.core.dram.DRAMSpec`, so the engine's per-step page-access
trace (:mod:`repro.core.trace`) converts into per-window touched-row
bitmaps that :func:`repro.core.refresh_sim.simulate_trace` consumes.

Policies (``PLACEMENT_POLICIES``):

* ``"row-major"`` — streams laid out sequentially, pages back to back
  (one global byte cursor).  Sub-row pages share rows; a stream's pool
  occupies one contiguous row run.  The locality baseline.
* ``"bank-interleaved"`` — DRMap/PENDRAM-style mapping: consecutive
  pool pages round-robin across the module's ``n_banks * n_channels``
  banks (each bank packs its own pages back to back in its private row
  span).  Buys bank-level parallelism at the cost of spreading the
  allocation across the whole module — the PAAR bound then covers every
  bank's partial span, which is exactly the trade the trace-driven
  comparison quantifies.
* ``"slot-colocated"`` — refresh-aware packing: pages with equal
  per-shard *local index* across ALL streams are placed adjacently.
  The allocator's per-stream free lists move in lockstep (same pop
  pattern for the same admission sequence), so equal local indices
  across streams belong to the same batch slot — this policy therefore
  packs one slot's pages (every layer's KV page + its state pages) into
  the fewest rows, minimizing the distinct rows a decode step touches.

A placement is geometry only — no jax, no engine state.  The serving
layer builds :class:`StreamGeometry` descriptors from its page table
(:meth:`repro.serve.paging.PageTable.stream_geometries`) and this
module never imports serve code.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.dram import DRAMSpec

__all__ = ["PLACEMENT_POLICIES", "Placement", "PlacementError",
           "StreamGeometry", "build_placement", "fitting_spec"]

PLACEMENT_POLICIES = ("row-major", "bank-interleaved", "slot-colocated")


class PlacementError(ValueError):
    """A placement that does not fit the module — raised with the bank/
    stream and the byte shortfall named, never silently wrapped."""


@dataclasses.dataclass(frozen=True)
class StreamGeometry:
    """Placement-relevant shape of one page-pool stream.

    ``n_pages`` is the pool extent *including* the per-shard reserved
    (ZERO/DUMP) pages; ``page_bytes`` the DRAM bytes one pool page
    holds (grouped KV streams stack their group's layers into one page
    id, so the stacked bytes ride in ``page_bytes``).
    """

    name: str
    n_pages: int
    page_bytes: int
    shards: int = 1
    reserved_per_shard: int = 0

    def __post_init__(self):
        if self.n_pages < 1 or self.page_bytes < 1:
            raise ValueError(
                f"stream {self.name!r}: n_pages={self.n_pages} and "
                f"page_bytes={self.page_bytes} must be >= 1")
        if self.shards < 1 or self.n_pages % self.shards:
            raise ValueError(
                f"stream {self.name!r}: n_pages={self.n_pages} must split "
                f"evenly over shards={self.shards}")

    @property
    def ext(self) -> int:
        """Per-shard pool extent (pages)."""
        return self.n_pages // self.shards


@dataclasses.dataclass(frozen=True)
class Placement:
    """Pages -> rows for one (policy, spec, stream set) triple.

    ``first_row[si][pid]`` / ``last_row[si][pid]`` bound (inclusive)
    the rows page ``pid`` of stream ``si`` occupies; the weight region
    spans rows ``[param_lo, param_hi)`` and is re-streamed every decode
    step.  ``alloc_lo``/``alloc_hi`` bound every mapped row — the PAAR
    allocation the refresh policies confine explicit refresh to.
    """

    policy: str
    spec: DRAMSpec
    streams: Tuple[StreamGeometry, ...]
    param_lo: int
    param_hi: int
    first_row: Tuple[np.ndarray, ...]
    last_row: Tuple[np.ndarray, ...]
    alloc_lo: int
    alloc_hi: int

    @property
    def alloc_rows(self) -> int:
        return self.alloc_hi - self.alloc_lo

    def page_rows(self, stream_idx: int, page_id: int) -> Tuple[int, int]:
        """(first_row, last_row) of one page, both inclusive."""
        return (int(self.first_row[stream_idx][page_id]),
                int(self.last_row[stream_idx][page_id]))

    def touch(self, row_mask: np.ndarray, stream_idx: int,
              page_ids: Sequence[int]) -> None:
        """Mark every row the given pages occupy in a [n_rows] bool mask."""
        fr, lr = self.first_row[stream_idx], self.last_row[stream_idx]
        for pid in page_ids:
            row_mask[fr[pid]:lr[pid] + 1] = True

    def touch_params(self, row_mask: np.ndarray) -> None:
        row_mask[self.param_lo:self.param_hi] = True

    def rows_used(self) -> int:
        """Distinct rows the mapping occupies (params + every page)."""
        mask = np.zeros((self.spec.n_rows,), bool)
        self.touch_params(mask)
        for si, g in enumerate(self.streams):
            self.touch(mask, si, range(g.n_pages))
        return int(mask.sum())


def _unit_order(policy: str, streams: Sequence[StreamGeometry]):
    """Yield (stream_idx, page_id) in the policy's packing order."""
    if policy == "slot-colocated":
        units = []
        for si, g in enumerate(streams):
            for pid in range(g.n_pages):
                shard, local = divmod(pid, g.ext)
                units.append((shard, local, si, pid))
        # reserved pages hold the smallest local indices, so (shard,
        # local, stream) ordering groups each shard's reserved pages
        # first, then interleaves the streams at equal local index —
        # the lockstep-free-list co-location argument (module docstring)
        units.sort()
        for _, _, si, pid in units:
            yield si, pid
    else:   # row-major and bank-interleaved share the sequential order
        for si, g in enumerate(streams):
            for pid in range(g.n_pages):
                yield si, pid


def build_placement(policy: str, spec: DRAMSpec,
                    streams: Sequence[StreamGeometry], *,
                    param_bytes: int = 0) -> Placement:
    """Map a weight region + every stream's pages onto ``spec``'s rows.

    The weight region (``param_bytes``, may be 0) always occupies the
    lowest rows — weights are re-streamed every step under every
    policy, so their rows are touched every window regardless of how
    pool pages are interleaved around them.
    """
    if policy not in PLACEMENT_POLICIES:
        raise PlacementError(
            f"unknown placement policy {policy!r}; "
            f"choose one of {PLACEMENT_POLICIES}")
    streams = tuple(streams)
    if len({g.shards for g in streams}) > 1:
        raise PlacementError(
            f"streams disagree on shard count: "
            f"{ {g.name: g.shards for g in streams} }")
    row_b = spec.row_bytes
    n_rows = spec.n_rows
    param_rows = -(-int(param_bytes) // row_b) if param_bytes else 0
    if param_rows > n_rows:
        raise PlacementError(
            f"weight region needs {param_rows} rows but the module has "
            f"{n_rows}")
    first = [np.zeros((g.n_pages,), np.int64) for g in streams]
    last = [np.zeros((g.n_pages,), np.int64) for g in streams]

    if policy == "bank-interleaved":
        B = spec.n_banks * spec.n_channels
        rpb = spec.rows_per_bank
        if rpb < 1:
            raise PlacementError(
                f"module has {n_rows} rows over {B} banks — no full bank "
                f"row span to interleave into")
        # bank b's private row span is [b*rpb, (b+1)*rpb); the weight
        # region fills the low banks row-major, so each bank's byte
        # cursor starts past its share of the weight rows
        cursor = [min(rpb, max(0, param_rows - b * rpb)) * row_b
                  for b in range(B)]
        for i, (si, pid) in enumerate(_unit_order(policy, streams)):
            b = i % B
            pb = streams[si].page_bytes
            lo, hi = cursor[b], cursor[b] + pb - 1
            if hi // row_b >= rpb:
                raise PlacementError(
                    f"bank-interleaved: bank {b} overflows its {rpb}-row "
                    f"span placing page {pid} of stream "
                    f"{streams[si].name!r} ({pb} bytes at bank offset "
                    f"{lo}); use a larger module (fitting_spec sizes one)")
            first[si][pid] = b * rpb + lo // row_b
            last[si][pid] = b * rpb + hi // row_b
            cursor[b] = hi + 1
    else:
        cursor = param_rows * row_b
        for si, pid in _unit_order(policy, streams):
            pb = streams[si].page_bytes
            lo, hi = cursor, cursor + pb - 1
            if hi // row_b >= n_rows:
                raise PlacementError(
                    f"{policy}: module of {n_rows} rows overflows placing "
                    f"page {pid} of stream {streams[si].name!r} "
                    f"({pb} bytes at byte offset {lo}); use a larger "
                    f"module (fitting_spec sizes one)")
            first[si][pid] = lo // row_b
            last[si][pid] = hi // row_b
            cursor = hi + 1

    lows = [int(f.min()) for f in first if f.size]
    highs = [int(l.max()) for l in last if l.size]
    alloc_lo = min([0] if param_rows else lows) if (param_rows or lows) else 0
    alloc_hi = max([param_rows] + [h + 1 for h in highs])
    return Placement(
        policy=policy, spec=spec, streams=streams,
        param_lo=0, param_hi=param_rows,
        first_row=tuple(first), last_row=tuple(last),
        alloc_lo=alloc_lo, alloc_hi=alloc_hi)


def fitting_spec(streams: Sequence[StreamGeometry], *,
                 param_bytes: int = 0, row_bytes: int = 2048,
                 n_banks: int = 8, n_channels: int = 2,
                 **spec_kw) -> DRAMSpec:
    """Smallest module (whole bank row spans) every policy fits on.

    Sized so the worst bank load of the interleaved policy — the full
    weight region landing in one bank plus that bank's share of the
    pool pages — still fits its row span; the sequential policies need
    strictly fewer rows.  Meant for trace-scale studies where the
    module is sized to the (smoke) pools, not a canonical 2/4/8 GB
    part.
    """
    streams = tuple(streams)
    B = n_banks * n_channels
    param_rows = -(-int(param_bytes) // row_bytes) if param_bytes else 0
    bank_bytes = [0] * B
    for i, (si, pid) in enumerate(_unit_order("bank-interleaved", streams)):
        bank_bytes[i % B] += streams[si].page_bytes
    rpb = param_rows + max(-(-b // row_bytes) for b in bank_bytes) + 1
    return DRAMSpec(capacity_bytes=B * rpb * row_bytes,
                    row_bytes=row_bytes, n_banks=n_banks,
                    n_channels=n_channels, **spec_kw)
