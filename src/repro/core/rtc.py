"""RTC policy engine: Min-RTC, Mid-RTC, Full-RTC (+ comparison points).

Analytical (rate-based) evaluation of every refresh policy the paper
discusses, against the same component energy model the baseline uses
(:mod:`repro.core.energy`).  The event-level simulator in
:mod:`repro.core.refresh_sim` validates these closed forms on downsized
modules (cross-check test), exactly as the paper validates its analytic
claims with its trace simulator.

Policy semantics (Section IV):

* ``BASELINE``      — JEDEC auto-refresh: all N_r rows, every window.
* ``MIN_RTC``       — MC-only (IV-A).  If the (regular) access stream is
  at least as fast as the refresh rate, the MC aligns accesses with the
  refresh schedule (III-B) and stops issuing REF entirely.  Below that
  rate, command-schedule-only alignment captures a calibrated fraction
  ``eta_min`` of the coalescing opportunities (Fig. 10c: ~20% DRAM
  energy for AN/GN @2 GB, degrading with capacity).
* ``MID_RTC``       — Min-RTC + PASR-style *bank*-granular PAAR usable
  during normal operation (IV-B): empty banks never refresh.
* ``FULL_RTC``      — in-DRAM RTT counter + AGU + rate FSM (IV-C).
  RTT coalesces min(N_a, N_r) refresh obligations per window (Algorithm
  1 density) and the AGU removes the cmd/addr-bus share of I/O energy;
  PAAR refreshes only the [lo, hi) allocated row bound.  Per the paper's
  Fig. 10a discussion, Full-RTC *selects* the stronger of RTT / PAAR for
  the workload ("RTC uses the RTT technique" for AN, PAAR for LN).
* ``FULL_RTC_PLUS`` — beyond-paper: run RTT *within* the PAAR bound and
  PAAR outside it simultaneously (a strict superset of FULL_RTC; the
  hardware already supports it — the RTT counter iterates only the
  bounded region).
* ``SMART_REFRESH`` — [17]: skip rows accessed in the last window, at
  the cost of one 3-bit SRAM counter per row (Section VI-B: the counter
  array's energy offsets the savings at scale).
* ``NO_REFRESH``    — oracle lower bound (non-volatile DRAM).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core.allocator import AllocationMap, allocate_workload
from repro.core.dram import DRAMSpec
from repro.core.energy import DEFAULT_PARAMS, EnergyParams, PowerBreakdown, dram_power
from repro.core.rate_matching import coalesced_access_fraction, implicit_fraction
from repro.core.workload import WorkloadProfile

__all__ = ["Variant", "RTCReport", "evaluate", "rtt_paar_split"]

# MC-side alignment efficiency for Min/Mid-RTC below the matched-rate
# threshold: a command-schedule-only implementation cannot retarget the
# in-DRAM refresh counter, so only part of the implicit-refresh
# opportunity is realizable.  Calibrated once against Fig. 10c (Min-RTC
# ~20% DRAM-energy reduction for AlexNet/GoogleNet on 2 GB).
ETA_MIN_RTC = 0.5


class Variant(enum.Enum):
    BASELINE = "baseline"
    MIN_RTC = "min-rtc"
    MID_RTC = "mid-rtc"
    FULL_RTC = "full-rtc"
    FULL_RTC_PLUS = "full-rtc+"      # beyond-paper
    SMART_REFRESH = "smart-refresh"
    NO_REFRESH = "no-refresh"


@dataclasses.dataclass(frozen=True)
class RTCReport:
    variant: Variant
    baseline: PowerBreakdown
    policy: PowerBreakdown
    # Individual technique contributions (for Fig. 10's RTT/PAAR bars),
    # expressed as fractions of *baseline total DRAM energy* saved.
    rtt_savings: float
    paar_savings: float

    @property
    def dram_savings(self) -> float:
        """Fraction of total DRAM energy saved (Fig. 10 y-axis)."""
        return 1.0 - self.policy.total / self.baseline.total

    @property
    def refresh_savings(self) -> float:
        """Fraction of refresh energy eliminated (abstract: 25%..96%)."""
        if self.baseline.refresh == 0:
            return 0.0
        return 1.0 - self.policy.refresh / self.baseline.refresh


def _rates(spec: DRAMSpec, workload: WorkloadProfile):
    n_r = float(spec.n_rows)                      # refresh obligations / window
    n_a = workload.rows_accessed_per_window(spec)  # row activations / window
    return n_a, n_r


def rtt_paar_split(
    spec: DRAMSpec,
    workload: WorkloadProfile,
    alloc: AllocationMap,
    params: EnergyParams = DEFAULT_PARAMS,
) -> tuple[float, float]:
    """(RTT-only, PAAR-only) Full-RTC savings as fractions of baseline
    DRAM energy — the paper plots these separately in Fig. 10."""
    base = dram_power(spec, workload, params)
    n_a, n_r = _rates(spec, workload)
    # RTT: Algorithm-1 implicit density over the whole module + AGU
    # cmd/addr elimination (only for AGU-expressible patterns).
    if workload.regular:
        f_c = implicit_fraction(n_a, n_r)
        rtt_power_saved = f_c * base.refresh + params.kappa_cmdaddr * base.io
    else:
        rtt_power_saved = 0.0
    # PAAR: refresh only the [lo, hi) allocated bound.
    paar_power_saved = (1.0 - alloc.row_paar_refresh_fraction()) * base.refresh
    return rtt_power_saved / base.total, paar_power_saved / base.total


def evaluate(
    spec: DRAMSpec,
    workload: WorkloadProfile,
    variant: Variant,
    alloc: Optional[AllocationMap] = None,
    params: EnergyParams = DEFAULT_PARAMS,
) -> RTCReport:
    if alloc is None:
        alloc = allocate_workload(spec, {workload.name: workload.footprint_bytes})
    base = dram_power(spec, workload, params)
    n_a, n_r = _rates(spec, workload)
    f_c = implicit_fraction(n_a, n_r) if workload.regular else 0.0
    matched = workload.regular and n_a >= n_r
    fits_window = workload.iter_period_s <= spec.effective_retention_s

    rtt_frac, paar_frac = rtt_paar_split(spec, workload, alloc, params)
    refresh_rows_s = spec.refresh_rows_per_second
    cmdaddr_saved = False
    extra = 0.0

    if variant is Variant.BASELINE:
        remaining = 1.0
    elif variant is Variant.NO_REFRESH:
        remaining = 0.0
    elif variant is Variant.MIN_RTC:
        remaining = 1.0 - _min_rtc_eliminated(f_c, matched, fits_window)
    elif variant is Variant.MID_RTC:
        bank_frac = alloc.bank_paar_refresh_fraction()
        rtt_elim = _min_rtc_eliminated(f_c, matched, fits_window)
        # RTT coalescing applies to obligations inside allocated banks;
        # empty banks are eliminated outright by bank-PAAR.
        remaining = bank_frac * (1.0 - rtt_elim)
    elif variant is Variant.FULL_RTC:
        # Paper semantics: the runtime selects the stronger technique.
        if rtt_frac >= paar_frac:
            remaining = 1.0 - f_c
            cmdaddr_saved = workload.regular
        else:
            remaining = alloc.row_paar_refresh_fraction()
    elif variant is Variant.FULL_RTC_PLUS:
        bound_frac = alloc.row_paar_refresh_fraction()
        # PAAR outside the bound; Algorithm-1 RTT inside it.
        f_c_bound = implicit_fraction(n_a, n_r * bound_frac) if workload.regular else 0.0
        remaining = bound_frac * (1.0 - f_c_bound)
        cmdaddr_saved = workload.regular
    elif variant is Variant.SMART_REFRESH:
        distinct = workload.distinct_rows_per_window(spec)
        remaining = 1.0 - min(1.0, distinct / n_r)
        extra = (
            spec.n_rows * params.p_counter_per_row
            + spec.n_rows
            * params.counter_ticks_per_window
            * params.e_counter_op
            / spec.effective_retention_s
        )
    else:  # pragma: no cover
        raise ValueError(variant)

    policy = dram_power(
        spec,
        workload,
        params,
        refresh_rows_per_s=refresh_rows_s * remaining,
        cmdaddr_saved=cmdaddr_saved,
        extra=extra,
    )
    return RTCReport(
        variant=variant,
        baseline=base,
        policy=policy,
        rtt_savings=rtt_frac,
        paar_savings=paar_frac,
    )


def _min_rtc_eliminated(f_c: float, matched: bool, fits_window: bool) -> float:
    """Refresh fraction a memory-controller-only implementation removes."""
    if not fits_window:
        return 0.0
    if matched:
        return 1.0  # Section IV-A: stop issuing REF altogether
    return ETA_MIN_RTC * f_c
