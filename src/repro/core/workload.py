"""Workload descriptors: the phase-level memory profile RTC consumes.

A :class:`WorkloadProfile` abstracts *any* application (CNN frame loop,
LM training step, LM decode step, Eigenfaces, BCPNN, BFAST...) down to
exactly the quantities the RTC mechanisms depend on:

* ``footprint_bytes``         — live data (PAAR: rows that must refresh);
* ``iter_period_s``           — one application iteration (frame / step);
* ``read_bytes_per_iter`` / ``write_bytes_per_iter`` — DRAM traffic,
  after data-locality exploitation is applied (RTT: implicit refreshes);
* ``regular``                 — whether the pattern is AGU-expressible
  (Section III-E: BFAST's random accesses are not; RTC is bypassed);
* ``row_utilization``         — effective fraction of a 2 KiB row
  transferred per activation.  Row-stationary CNN tiling streams large
  contiguous filter/fmap blocks but splits rows across tiles; 0.5 is the
  paper-consistent default (see energy-model calibration notes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cnn_zoo import CNNProfile
from repro.core.dram import DRAMSpec
from repro.models.config import ModelConfig

__all__ = ["WorkloadProfile", "WorkloadError", "from_cnn", "from_decode",
           "lm_workload", "merge"]


class WorkloadError(ValueError):
    """A workload description that cannot be accounted — raised with the
    offending quantity named (e.g. a decode profile claiming zero cached
    context), instead of silently clamping it to something billable."""


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    footprint_bytes: int
    iter_period_s: float
    read_bytes_per_iter: float
    write_bytes_per_iter: float
    regular: bool = True
    row_utilization: float = 0.5

    @property
    def traffic_bytes_per_s(self) -> float:
        return (self.read_bytes_per_iter + self.write_bytes_per_iter) / self.iter_period_s

    def row_activations_per_s(self, spec: DRAMSpec) -> float:
        """ACT rate implied by the traffic under ``row_utilization``."""
        eff_bytes_per_act = spec.row_bytes * self.row_utilization
        return self.traffic_bytes_per_s / eff_bytes_per_act

    def rows_accessed_per_window(self, spec: DRAMSpec) -> float:
        """N_a of Algorithm 1: row activations per retention window."""
        return self.row_activations_per_s(spec) * spec.effective_retention_s

    def distinct_rows_per_window(self, spec: DRAMSpec) -> float:
        """Distinct rows touched in a window (bounded by the footprint
        when the iteration covers the whole working set)."""
        covers_per_window = spec.effective_retention_s / self.iter_period_s
        footprint_rows = spec.rows_for_bytes(self.footprint_bytes)
        if covers_per_window >= 1.0:
            return float(min(footprint_rows, self.rows_accessed_per_window(spec)))
        return float(min(footprint_rows * covers_per_window,
                         self.rows_accessed_per_window(spec)))

    def scaled(self, n_instances: int) -> "WorkloadProfile":
        """Co-run ``n`` instances (Fig. 11 multi-CNN setup)."""
        return dataclasses.replace(
            self,
            name=f"{self.name}x{n_instances}",
            footprint_bytes=self.footprint_bytes * n_instances,
            read_bytes_per_iter=self.read_bytes_per_iter * n_instances,
            write_bytes_per_iter=self.write_bytes_per_iter * n_instances,
        )


def from_cnn(
    profile: CNNProfile,
    fps: float,
    locality: float = 1.0,
    row_utilization: float = 0.5,
) -> WorkloadProfile:
    """Paper Section VI: CNN at a frame rate with locality exploitation."""
    return WorkloadProfile(
        name=f"{profile.name}@{fps:g}fps/L{locality:.0%}",
        footprint_bytes=profile.footprint_bytes,
        iter_period_s=1.0 / fps,
        read_bytes_per_iter=profile.read_bytes_per_frame / locality,
        write_bytes_per_iter=float(profile.write_bytes_per_frame),
        regular=True,
        row_utilization=row_utilization,
    )


def from_decode(
    name: str,
    *,
    param_read_bytes: float,
    kv_read_bytes: float,
    kv_write_bytes: float,
    footprint_bytes: int,
    step_period_s: float,
    page_out_bytes: float = 0.0,
    page_in_bytes: float = 0.0,
    regular: bool = True,
    row_utilization: float = 1.0,
) -> WorkloadProfile:
    """LM decode phase: one profile iteration == one decode step.

    Every step re-streams the active weights (``param_read_bytes``) and
    sweeps the live KV/recurrent state in a fixed order
    (``kv_read_bytes``), appending one token per slot per attention
    layer (``kv_write_bytes``) — the pseudo-stationary recurring pattern
    of Section III-A, so ``regular`` defaults to True and weight
    streaming keeps full row utilization.  Built for engine telemetry
    (:mod:`repro.serve.telemetry`), which measures these quantities
    from a real serving loop instead of hand-deriving them.

    ``page_out_bytes`` / ``page_in_bytes``: per-step host-offload
    traffic of a paged cache (pages leaving device DRAM are reads,
    pages coming back are writes).  Page moves are whole-page streams
    through the same AGU-expressible block tables as the KV sweep, so
    they stay inside the ``regular`` access contract; they add to the
    traffic RTC's implicit-refresh window sees, which is why ignoring
    them would overstate refresh savings for an offloading engine.
    """
    if step_period_s <= 0:
        raise ValueError("step_period_s must be positive")
    return WorkloadProfile(
        name=name,
        footprint_bytes=int(footprint_bytes),
        iter_period_s=float(step_period_s),
        read_bytes_per_iter=(float(param_read_bytes) + float(kv_read_bytes)
                             + float(page_out_bytes)),
        write_bytes_per_iter=float(kv_write_bytes) + float(page_in_bytes),
        regular=regular,
        row_utilization=row_utilization,
    )


def merge(name: str, *workloads: WorkloadProfile) -> WorkloadProfile:
    """Co-schedule several workloads on one module (Fig. 11).

    Traffic adds; the iteration period becomes the max (the slowest
    refresher of its own data); regular only if all parts are regular
    (Section III-E maps apps to disjoint banks, preserving regularity —
    we model the aggregate stream).

    ``row_utilization`` combines byte-weighted: the merged profile's ACT
    rate must equal the sum of the components' ACT rates (each stream
    still opens its own rows at its own utilization), and since ACT rate
    is ``traffic / (row_bytes * utilization)``, the utilization that
    preserves the aggregate is the traffic-weighted *harmonic* mean.
    The previous ``min()`` billed every higher-utilization component at
    the worst stream's row efficiency, overstating the mix's ACT rate —
    and with it the implicit-refresh credit — whenever utilizations
    differed.  (The pinned fig11 mixes all run the paper-consistent CNN
    default of 0.5, for which the weighted mean is exactly 0.5, so their
    calibration values are unchanged by this fix.)
    """
    if not workloads:
        raise ValueError("need at least one workload")
    period = max(w.iter_period_s for w in workloads)
    traffic = [w.traffic_bytes_per_s for w in workloads]
    total = sum(traffic)
    if total > 0:
        row_util = total / sum(t / w.row_utilization
                               for t, w in zip(traffic, workloads))
    else:
        row_util = min(w.row_utilization for w in workloads)
    return WorkloadProfile(
        name=name,
        footprint_bytes=sum(w.footprint_bytes for w in workloads),
        iter_period_s=period,
        read_bytes_per_iter=sum(
            w.read_bytes_per_iter * period / w.iter_period_s for w in workloads
        ),
        write_bytes_per_iter=sum(
            w.write_bytes_per_iter * period / w.iter_period_s for w in workloads
        ),
        regular=all(w.regular for w in workloads),
        row_utilization=row_util,
    )


# ---------------------------------------------------------------------------
# LM phase profiles (beyond-paper): ModelConfig -> WorkloadProfile
# ---------------------------------------------------------------------------
BYTES_PER_PARAM = 2     # bf16 weights
BYTES_PER_OPT = 8       # f32 m + v (per param)


def lm_workload(
    cfg: ModelConfig,
    kind: str,                 # "train" | "decode"
    step_time_s: float,
    *,
    global_batch: int = 1,
    seq_len: int = 0,
    row_utilization: float = 1.0,   # weight streaming is fully sequential
) -> WorkloadProfile:
    """Phase-level DRAM profile of one train/decode step.

    train:  read weights + opt state, write weights + opt state
            (every step touches the full resident set — RTT-ideal).
    decode: read *active* weights + the KV cache, append one token of KV
            (MoE: inactive experts are resident but untouched ->
            Algorithm-1 partial-coverage regime, the paper's most
            interesting case).  ``seq_len`` is the cached context the
            step attends over and must be >= 1 — a decode step always
            has at least the token it was sampled from.  It used to be
            silently clamped (``max(seq_len, 1)``), which billed one
            token of KV sweep/footprint for a context the caller said
            did not exist; now a :class:`WorkloadError` names the bad
            value instead of inventing traffic.
    """
    n_total = cfg.param_counts()["total"]
    n_active = cfg.active_param_counts()
    w_bytes = n_total * BYTES_PER_PARAM

    if kind == "train":
        opt_bytes = n_total * BYTES_PER_OPT
        footprint = w_bytes + opt_bytes
        reads = w_bytes + opt_bytes
        writes = w_bytes + opt_bytes
    elif kind == "decode":
        if seq_len < 1:
            raise WorkloadError(
                f"lm_workload({cfg.name!r}, 'decode'): seq_len={seq_len} "
                f"but a decode step attends over at least 1 cached token; "
                f"pass the real context length instead of relying on the "
                f"old max(seq_len, 1) clamp")
        kv_token = _kv_bytes_per_token(cfg)
        kv_bytes = kv_token * global_batch * seq_len
        footprint = w_bytes + kv_bytes
        reads = n_active * BYTES_PER_PARAM + kv_bytes
        writes = kv_token * global_batch
    else:
        raise ValueError(kind)

    return WorkloadProfile(
        name=f"{cfg.name}/{kind}",
        footprint_bytes=int(footprint),
        iter_period_s=step_time_s,
        read_bytes_per_iter=float(reads),
        write_bytes_per_iter=float(writes),
        regular=True,
        row_utilization=row_utilization,
    )


def _kv_bytes_per_token(cfg: ModelConfig) -> int:
    """Per-token recurrent/KV state bytes across the stack."""
    total = 0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "global":
            total += 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        elif kind == "local":
            # bounded window: amortized per-token cost is the same
            # write traffic; reads bounded by the window
            total += 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        # ssm / rglru carry O(1) state: no per-token growth
    return total
