"""Workload descriptors: the phase-level memory profile RTC consumes.

A :class:`WorkloadProfile` abstracts *any* application (CNN frame loop,
LM training step, LM decode step, Eigenfaces, BCPNN, BFAST...) down to
exactly the quantities the RTC mechanisms depend on:

* ``footprint_bytes``         — live data (PAAR: rows that must refresh);
* ``iter_period_s``           — one application iteration (frame / step);
* ``read_bytes_per_iter`` / ``write_bytes_per_iter`` — DRAM traffic,
  after data-locality exploitation is applied (RTT: implicit refreshes);
* ``regular``                 — whether the pattern is AGU-expressible
  (Section III-E: BFAST's random accesses are not; RTC is bypassed);
* ``row_utilization``         — effective fraction of a 2 KiB row
  transferred per activation.  Row-stationary CNN tiling streams large
  contiguous filter/fmap blocks but splits rows across tiles; 0.5 is the
  paper-consistent default (see energy-model calibration notes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cnn_zoo import CNNProfile
from repro.core.dram import DRAMSpec

__all__ = ["WorkloadProfile", "from_cnn", "from_decode", "merge"]


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    footprint_bytes: int
    iter_period_s: float
    read_bytes_per_iter: float
    write_bytes_per_iter: float
    regular: bool = True
    row_utilization: float = 0.5

    @property
    def traffic_bytes_per_s(self) -> float:
        return (self.read_bytes_per_iter + self.write_bytes_per_iter) / self.iter_period_s

    def row_activations_per_s(self, spec: DRAMSpec) -> float:
        """ACT rate implied by the traffic under ``row_utilization``."""
        eff_bytes_per_act = spec.row_bytes * self.row_utilization
        return self.traffic_bytes_per_s / eff_bytes_per_act

    def rows_accessed_per_window(self, spec: DRAMSpec) -> float:
        """N_a of Algorithm 1: row activations per retention window."""
        return self.row_activations_per_s(spec) * spec.effective_retention_s

    def distinct_rows_per_window(self, spec: DRAMSpec) -> float:
        """Distinct rows touched in a window (bounded by the footprint
        when the iteration covers the whole working set)."""
        covers_per_window = spec.effective_retention_s / self.iter_period_s
        footprint_rows = spec.rows_for_bytes(self.footprint_bytes)
        if covers_per_window >= 1.0:
            return float(min(footprint_rows, self.rows_accessed_per_window(spec)))
        return float(min(footprint_rows * covers_per_window,
                         self.rows_accessed_per_window(spec)))

    def scaled(self, n_instances: int) -> "WorkloadProfile":
        """Co-run ``n`` instances (Fig. 11 multi-CNN setup)."""
        return dataclasses.replace(
            self,
            name=f"{self.name}x{n_instances}",
            footprint_bytes=self.footprint_bytes * n_instances,
            read_bytes_per_iter=self.read_bytes_per_iter * n_instances,
            write_bytes_per_iter=self.write_bytes_per_iter * n_instances,
        )


def from_cnn(
    profile: CNNProfile,
    fps: float,
    locality: float = 1.0,
    row_utilization: float = 0.5,
) -> WorkloadProfile:
    """Paper Section VI: CNN at a frame rate with locality exploitation."""
    return WorkloadProfile(
        name=f"{profile.name}@{fps:g}fps/L{locality:.0%}",
        footprint_bytes=profile.footprint_bytes,
        iter_period_s=1.0 / fps,
        read_bytes_per_iter=profile.read_bytes_per_frame / locality,
        write_bytes_per_iter=float(profile.write_bytes_per_frame),
        regular=True,
        row_utilization=row_utilization,
    )


def from_decode(
    name: str,
    *,
    param_read_bytes: float,
    kv_read_bytes: float,
    kv_write_bytes: float,
    footprint_bytes: int,
    step_period_s: float,
    page_out_bytes: float = 0.0,
    page_in_bytes: float = 0.0,
    regular: bool = True,
    row_utilization: float = 1.0,
) -> WorkloadProfile:
    """LM decode phase: one profile iteration == one decode step.

    Every step re-streams the active weights (``param_read_bytes``) and
    sweeps the live KV/recurrent state in a fixed order
    (``kv_read_bytes``), appending one token per slot per attention
    layer (``kv_write_bytes``) — the pseudo-stationary recurring pattern
    of Section III-A, so ``regular`` defaults to True and weight
    streaming keeps full row utilization.  Built for engine telemetry
    (:mod:`repro.serve.telemetry`), which measures these quantities
    from a real serving loop instead of hand-deriving them.

    ``page_out_bytes`` / ``page_in_bytes``: per-step host-offload
    traffic of a paged cache (pages leaving device DRAM are reads,
    pages coming back are writes).  Page moves are whole-page streams
    through the same AGU-expressible block tables as the KV sweep, so
    they stay inside the ``regular`` access contract; they add to the
    traffic RTC's implicit-refresh window sees, which is why ignoring
    them would overstate refresh savings for an offloading engine.
    """
    if step_period_s <= 0:
        raise ValueError("step_period_s must be positive")
    return WorkloadProfile(
        name=name,
        footprint_bytes=int(footprint_bytes),
        iter_period_s=float(step_period_s),
        read_bytes_per_iter=(float(param_read_bytes) + float(kv_read_bytes)
                             + float(page_out_bytes)),
        write_bytes_per_iter=float(kv_write_bytes) + float(page_in_bytes),
        regular=regular,
        row_utilization=row_utilization,
    )


def merge(name: str, *workloads: WorkloadProfile) -> WorkloadProfile:
    """Co-schedule several workloads on one module (Fig. 11).

    Traffic adds; the iteration period becomes the max (the slowest
    refresher of its own data); regular only if all parts are regular
    (Section III-E maps apps to disjoint banks, preserving regularity —
    we model the aggregate stream).
    """
    if not workloads:
        raise ValueError("need at least one workload")
    period = max(w.iter_period_s for w in workloads)
    return WorkloadProfile(
        name=name,
        footprint_bytes=sum(w.footprint_bytes for w in workloads),
        iter_period_s=period,
        read_bytes_per_iter=sum(
            w.read_bytes_per_iter * period / w.iter_period_s for w in workloads
        ),
        write_bytes_per_iter=sum(
            w.write_bytes_per_iter * period / w.iter_period_s for w in workloads
        ),
        regular=all(w.regular for w in workloads),
        row_utilization=min(w.row_utilization for w in workloads),
    )
