"""DRAM energy model (the Rambus-model stand-in of Section V).

The paper feeds ACT/RD/WR/PRE/REF operation counts from its accelerator
simulator into the Rambus power model [60] at the 40 nm node and reports
*relative* energy results.  We reproduce that pipeline with an explicit
component model:

    P_dram = P_refresh + P_act + P_io + P_background

with per-operation coefficients chosen to be simultaneously consistent
with the paper's published anchor points:

* refresh share of AlexNet's DRAM energy @2 GB/60 fps ~= 44%  (Fig. 10a:
  RTT at matched rates saves ~all refresh = 44% of DRAM energy);
* LeNet DRAM energy is ~96-97% refresh @2 GB (Fig. 10a: PAAR saves 96%);
* refresh ~= "40% of total DRAM energy" (abstract, [24,35]) and ~46-47%
  for a 64 Gb chip at peak bandwidth (Section VI-C / Fig. 12);
* Fig. 1 system-level refresh shares: AN ~15%, GN ~15%, LN ~47%.

Physical interpretation of the calibrated values: a refresh and a demand
activation perform the *same* array-level charge-restore (Section II-A),
so ``e_ref_row == e_act_row`` (~30 nJ for a 2 KiB row ~= 1.8 pJ/bit of
sense-amp restore at 40 nm — Vogelsang-model array energy, which is the
regime the paper's numbers imply, considerably above commodity-datasheet
refresh currents; both regimes are expressible by overriding the
dataclass).  I/O + column-path energy ~9 pJ/B and a command/address-bus
share ``kappa`` saved when the in-DRAM AGU generates addresses
(Section IV-C2: "the memory controller issues the DRAM commands along
with the address via the DDR interface, which incurs additional energy
consumption compared to RTC").
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.dram import DRAMSpec, GiB
from repro.core.workload import WorkloadProfile

__all__ = ["EnergyParams", "PowerBreakdown", "dram_power", "system_power"]


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    # --- DRAM array / interface -------------------------------------------
    e_act_row: float = 30e-9      # J per demand row activation (ACT..PRE)
    e_ref_row: float = 30e-9      # J per row replenished by REF (same circuit op)
    e_io_byte: float = 9e-12      # J per byte moved through column path + I/O
    kappa_cmdaddr: float = 0.15   # fraction of I/O energy on the cmd/addr bus
                                  # (eliminated when the RTT AGU self-generates)
    p_background_per_gb: float = 6e-3   # W/GB periphery + standby
    # --- SmartRefresh comparison (Section VI-B) ----------------------------
    e_counter_op: float = 5e-12   # J per 3-bit counter update
    p_counter_per_row: float = 10e-9    # W SRAM leakage per row counter
    counter_ticks_per_window: int = 8   # 3-bit timeout granularity
    # --- system level (Fig. 1) ---------------------------------------------
    e_mac: float = 30e-12         # J per accelerator MAC incl. on-chip SRAM
    p_platform_static: float = 0.54     # W LEON3 host + bus + accelerator idle


DEFAULT_PARAMS = EnergyParams()


@dataclasses.dataclass(frozen=True)
class PowerBreakdown:
    """All components in watts; energy over any horizon scales linearly."""

    refresh: float
    act: float
    io: float
    background: float
    extra: float = 0.0   # policy bookkeeping (e.g. SmartRefresh counters)

    @property
    def total(self) -> float:
        return self.refresh + self.act + self.io + self.background + self.extra

    @property
    def refresh_fraction(self) -> float:
        return self.refresh / self.total if self.total else 0.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self) | {
            "total": self.total,
            "refresh_fraction": self.refresh_fraction,
        }


def dram_power(
    spec: DRAMSpec,
    workload: WorkloadProfile,
    params: EnergyParams = DEFAULT_PARAMS,
    *,
    refresh_rows_per_s: float | None = None,
    act_rows_per_s: float | None = None,
    io_bytes_per_s: float | None = None,
    cmdaddr_saved: bool = False,
    extra: float = 0.0,
) -> PowerBreakdown:
    """Baseline (or overridden) DRAM power for a workload on a module.

    Policies in :mod:`repro.core.rtc` call this with overridden refresh
    rates / coalesced activation counts.
    """
    if refresh_rows_per_s is None:
        refresh_rows_per_s = spec.refresh_rows_per_second
    if act_rows_per_s is None:
        act_rows_per_s = workload.row_activations_per_s(spec)
    if io_bytes_per_s is None:
        io_bytes_per_s = workload.traffic_bytes_per_s
    io = io_bytes_per_s * params.e_io_byte
    if cmdaddr_saved:
        io *= 1.0 - params.kappa_cmdaddr
    return PowerBreakdown(
        refresh=refresh_rows_per_s * params.e_ref_row,
        act=act_rows_per_s * params.e_act_row,
        io=io,
        background=(spec.capacity_bytes / GiB) * params.p_background_per_gb,
        extra=extra,
    )


def accelerator_power(
    macs_per_s: float, params: EnergyParams = DEFAULT_PARAMS
) -> float:
    return macs_per_s * params.e_mac + params.p_platform_static


def system_power(
    spec: DRAMSpec,
    workload: WorkloadProfile,
    macs_per_s: float,
    params: EnergyParams = DEFAULT_PARAMS,
) -> Dict[str, float]:
    """Fig. 1 decomposition: refresh / DRAM-access / compute shares."""
    dram = dram_power(spec, workload, params)
    accel = accelerator_power(macs_per_s, params)
    total = dram.total + accel
    return {
        "refresh_w": dram.refresh,
        "dram_access_w": dram.act + dram.io + dram.background,
        "accelerator_w": accel,
        "total_w": total,
        "refresh_share": dram.refresh / total,
        "dram_share": dram.total / total,
    }
