"""Event-level (retention-window-granular) RTC simulator.

Validates the closed-form policy evaluations of :mod:`repro.core.rtc`
mechanistically: per-row age state advances window by window under the
policy's explicit-refresh predicate and the workload's streaming access
cursor, and the simulator asserts the *data-integrity invariant* — no
allocated row ever exceeds its retention deadline — which is the
property the paper's Section III-B/Fig. 4 alignment argument exists to
protect.  (Granularity note: rows are marked replenished per window
under the Section III-B alignment assumption — the RTT counter orders
accesses along the refresh schedule, so an every-window access implies a
within-deadline replenish.)

The per-window row-state update is the compute hot spot (4M rows x
thousands of windows for Fig. 12-scale modules); it runs either as the
pure-jnp oracle or the tiled Pallas kernel (``repro.kernels.refresh_sim``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dram import DRAMSpec
from repro.core.energy import DEFAULT_PARAMS, EnergyParams
from repro.core.rtc import Variant

__all__ = ["SimResult", "simulate", "simulate_trace"]


@dataclasses.dataclass(frozen=True)
class SimResult:
    variant: Variant
    n_windows: int
    n_rows: int
    implicit_refreshes: int     # access-coalesced replenishes
    explicit_refreshes: int     # REF-driven replenishes
    violations: int             # allocated rows past retention (MUST be 0)
    refresh_energy_j: float
    baseline_refresh_energy_j: float

    @property
    def refresh_savings(self) -> float:
        if self.baseline_refresh_energy_j == 0:
            return 0.0
        return 1.0 - self.refresh_energy_j / self.baseline_refresh_energy_j


def _policy_bounds(
    variant: Variant, n_rows: int, alloc_lo: int, alloc_hi: int, matched: bool
) -> Tuple[int, int, bool]:
    """(ref_lo, ref_hi, skip_accessed) for the explicit-refresh predicate."""
    if variant is Variant.BASELINE:
        return 0, n_rows, False
    if variant is Variant.NO_REFRESH:
        return 0, 0, False
    if variant is Variant.MIN_RTC:
        # MC-only: either the stream covers everything (stop all REF) or
        # auto-refresh stays fully on (the conservative gate of IV-A).
        return (0, 0, False) if matched else (0, n_rows, False)
    if variant is Variant.MID_RTC:
        # Bank-granular PAAR modeled as refreshing the containing banks'
        # full row span (callers pass bank-rounded alloc bounds).
        return alloc_lo, alloc_hi, False
    if variant in (Variant.FULL_RTC, Variant.FULL_RTC_PLUS):
        # Row-granular PAAR bound + RTT skip of freshly-accessed rows.
        return alloc_lo, alloc_hi, True
    if variant is Variant.SMART_REFRESH:
        # Per-row timeout counters: skip recently-accessed, no PAAR.
        return 0, n_rows, True
    raise ValueError(variant)


def _refresh_bounds(
    spec: DRAMSpec,
    variant: Variant,
    *,
    alloc_lo: int,
    alloc_hi: int,
    matched: bool,
    bank_rounded: bool,
) -> Tuple[int, int, bool]:
    """Resolve the explicit-refresh predicate for one policy run.

    Bank rounding widens only the *explicit-refresh predicate* (PASR
    granularity: the policy refreshes whole banks).  The access stream
    and the integrity/violation domain are the workload's, and the
    workload still touches exactly its original allocation — sweeping
    the rounded span would credit implicit refreshes to rows the
    application never allocated.
    """
    n_rows = spec.n_rows
    if bank_rounded:
        span = max(1, spec.rows_per_bank)
        bound_lo = (alloc_lo // span) * span
        bound_hi = min(n_rows, -(-alloc_hi // span) * span)
    else:
        bound_lo, bound_hi = alloc_lo, alloc_hi
    return _policy_bounds(variant, n_rows, bound_lo, bound_hi, matched)


def simulate(
    spec: DRAMSpec,
    variant: Variant,
    *,
    alloc_rows: int,
    rows_accessed_per_window: int,
    n_windows: int = 64,
    alloc_lo: int = 0,
    params: EnergyParams = DEFAULT_PARAMS,
    backend: str = "ref",
    bank_rounded: bool = False,
) -> SimResult:
    """Run ``n_windows`` retention windows of one workload phase.

    The access stream is the RTT/AGU affine pattern: a cursor sweeping
    the allocated region [alloc_lo, alloc_lo+alloc_rows) by
    ``rows_accessed_per_window`` rows per window, wrapping around —
    exactly the recurring pattern of Section III-A/Fig. 4.
    """
    from repro.kernels.refresh_sim.ops import window_update

    n_rows = spec.n_rows
    alloc_hi = alloc_lo + alloc_rows
    if alloc_hi > n_rows:
        raise ValueError("allocation exceeds module")
    matched = rows_accessed_per_window >= n_rows
    ref_lo, ref_hi, skip = _refresh_bounds(
        spec, variant, alloc_lo=alloc_lo, alloc_hi=alloc_hi,
        matched=matched, bank_rounded=bank_rounded)

    def step(carry, _):
        age, cursor = carry
        new_age, imp, exp, vio = window_update(
            age, cursor, rows_accessed_per_window, alloc_lo, alloc_hi,
            ref_lo, ref_hi, skip, backend=backend,
        )
        span = max(1, alloc_hi - alloc_lo)
        # Oversized access counts saturate rather than alias: the kernel
        # marks row r accessed iff mod(r - cursor, span) < acc_len, and
        # for acc_len >= span that holds for EVERY allocated row (the
        # modulo distance is always < span), so one window covers the
        # whole allocation no matter where the % below parks the cursor.
        # Audited + pinned by test_oversized_access_saturates_allocation.
        cursor = alloc_lo + (cursor - alloc_lo + rows_accessed_per_window) % span
        return (new_age, cursor), jnp.stack(
            [jnp.asarray(imp, jnp.int32), jnp.asarray(exp, jnp.int32),
             jnp.asarray(vio, jnp.int32)]
        )

    age0 = jnp.zeros((n_rows,), jnp.int32)
    (_, _), counts = jax.lax.scan(
        step, (age0, jnp.asarray(alloc_lo, jnp.int32)), None, length=n_windows
    )
    counts = np.asarray(counts, dtype=np.int64).sum(axis=0)
    implicit, explicit, violations = (int(c) for c in counts)

    e_ref = explicit * params.e_ref_row
    e_base = n_rows * n_windows * params.e_ref_row
    return SimResult(
        variant=variant,
        n_windows=n_windows,
        n_rows=n_rows,
        implicit_refreshes=implicit,
        explicit_refreshes=explicit,
        violations=violations,
        refresh_energy_j=e_ref,
        baseline_refresh_energy_j=e_base,
    )


def simulate_trace(
    spec: DRAMSpec,
    variant: Variant,
    *,
    masks: np.ndarray,          # bool [n_windows, n_rows]: touched rows
    alloc_lo: int,
    alloc_rows: int,
    params: EnergyParams = DEFAULT_PARAMS,
    backend: str = "ref",
    bank_rounded: bool = False,
    matched: "bool | None" = None,
) -> SimResult:
    """Replay a measured access stream through the same row-state machine.

    ``masks`` is the per-window touched-rows bitmap a live trace implies
    under a placement (:func:`repro.core.trace.window_masks`), or the
    affine cursor's own bitmap (:func:`repro.core.trace.affine_masks`) —
    on the latter this reproduces :func:`simulate` exactly, which is the
    pinned equivalence contract (``tests/test_trace_sim.py``).

    ``matched`` feeds MIN_RTC's conservative all-or-nothing gate.  The
    affine model decides it from the access *rate* (``acc >= n_rows``),
    which a bitmap cannot express once ``alloc_rows < n_rows``; the
    default derives the only trace-expressible analogue — every row of
    the module touched in every window — and equivalence tests pass the
    affine value explicitly.
    """
    from repro.kernels.refresh_sim.ops import window_update_masked

    n_rows = spec.n_rows
    alloc_hi = alloc_lo + alloc_rows
    if alloc_hi > n_rows:
        raise ValueError("allocation exceeds module")
    masks = np.asarray(masks)
    if masks.ndim != 2 or masks.shape[1] != n_rows:
        raise ValueError(
            f"masks shape {masks.shape} != (n_windows, {n_rows})")
    n_windows = masks.shape[0]
    if matched is None:
        matched = bool(masks.all()) if masks.size else False
    ref_lo, ref_hi, skip = _refresh_bounds(
        spec, variant, alloc_lo=alloc_lo, alloc_hi=alloc_hi,
        matched=matched, bank_rounded=bank_rounded)

    def step(age, touched):
        new_age, imp, exp, vio = window_update_masked(
            age, touched, alloc_lo, alloc_hi, ref_lo, ref_hi, skip,
            backend=backend,
        )
        return new_age, jnp.stack(
            [jnp.asarray(imp, jnp.int32), jnp.asarray(exp, jnp.int32),
             jnp.asarray(vio, jnp.int32)]
        )

    age0 = jnp.zeros((n_rows,), jnp.int32)
    _, counts = jax.lax.scan(
        step, age0, jnp.asarray(masks, jnp.int32), length=n_windows
    )
    counts = np.asarray(counts, dtype=np.int64).sum(axis=0)
    implicit, explicit, violations = (int(c) for c in counts)

    e_ref = explicit * params.e_ref_row
    e_base = n_rows * n_windows * params.e_ref_row
    return SimResult(
        variant=variant,
        n_windows=n_windows,
        n_rows=n_rows,
        implicit_refreshes=implicit,
        explicit_refreshes=explicit,
        violations=violations,
        refresh_energy_j=e_ref,
        baseline_refresh_energy_j=e_base,
    )
