"""The paper's system layer: DRAM geometry, RTC policies, and the two
access models they are evaluated under.

Module map (energy path, left to right): :mod:`~repro.core.dram` (module
geometry/timing) -> :mod:`~repro.core.workload` (phase-level traffic
profiles) -> :mod:`~repro.core.rate_matching` / :mod:`~repro.core.rtc`
(closed-form RTT/PAAR evaluation) -> :mod:`~repro.core.energy`, with
:mod:`~repro.core.refresh_sim` as the event-level validator of the
closed forms and :mod:`~repro.core.allocator` mapping workloads to row
allocations.

Placement and traces (PR 9).  The closed-form model reasons about an
*affine* access stream — ``rows_accessed_per_window`` consecutive rows
sweeping the allocation.  Real serving accesses are page-granular and
scheduling-dependent, and which DRAM rows they replenish depends on a
policy the paper leaves to the memory controller: how data is mapped
onto banks and rows.  That axis is split across two deliberately
decoupled modules:

* :mod:`~repro.core.placement` — geometry only: maps every physical
  page of the serving stack's pool streams (plus the resident weight
  region) to row intervals of a :class:`~repro.core.dram.DRAMSpec`,
  under ``row-major``, DRMap/PENDRAM-style ``bank-interleaved``, or
  refresh-aware ``slot-colocated`` packing.  It never imports serve
  code; the serving layer describes its pools as
  :class:`~repro.core.placement.StreamGeometry` values.
* :mod:`~repro.core.trace` — the measured access stream: the engine
  logs which pages each decode step touched into a
  :class:`~repro.core.trace.PageAccessTrace`; ``window_masks(trace,
  placement)`` turns trace x placement into per-window touched-row
  bitmaps, and :func:`~repro.core.refresh_sim.simulate_trace` replays
  them through the same row-state machine as the affine simulator.

The bridge between the two worlds is the equivalence contract:
``simulate_trace`` on :func:`~repro.core.trace.affine_masks` reproduces
:func:`~repro.core.refresh_sim.simulate` exactly (pinned by
``tests/test_trace_sim.py``), so trace-driven and closed-form numbers
are directly comparable — which is what lets a live serve's trace stand
in for the paper's analytic workloads on the Fig. 10 axes
(``benchmarks/fig10_trace.py``).
"""
