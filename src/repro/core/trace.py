"""Workload profiles for the assigned LM architectures (beyond-paper).

The paper derives DRAM profiles from CNN frame loops; modern serving
and training loops have exactly the *pseudo-stationary spatio-temporal
access pattern* RTC targets (Section III-A): every step re-streams the
(active) weights and touches the optimizer state / KV cache in a fixed
order.  This module converts a :class:`ModelConfig` + shape into the
:class:`WorkloadProfile` the RTC engine consumes, so
``benchmarks/lm_rtc.py`` can quantify RTC savings for all 10 archs —
e.g. an accelerator whose weights live in LPDDR-class memory (edge
serving), the regime where the paper's mechanism directly applies.

Step period defaults to the dry-run roofline bound when available
(``step_time_s``), tying the RTC study to the measured system.
"""
from __future__ import annotations

from typing import Optional

from repro.core.workload import WorkloadProfile
from repro.models.config import ModelConfig

__all__ = ["lm_workload"]

BYTES_PER_PARAM = 2     # bf16 weights
BYTES_PER_OPT = 8       # f32 m + v (per param)


def lm_workload(
    cfg: ModelConfig,
    kind: str,                 # "train" | "decode"
    step_time_s: float,
    *,
    global_batch: int = 1,
    seq_len: int = 0,
    row_utilization: float = 1.0,   # weight streaming is fully sequential
) -> WorkloadProfile:
    """Phase-level DRAM profile of one train/decode step.

    train:  read weights + opt state, write weights + opt state
            (every step touches the full resident set — RTT-ideal).
    decode: read *active* weights + the KV cache, append one token of KV
            (MoE: inactive experts are resident but untouched ->
            Algorithm-1 partial-coverage regime, the paper's most
            interesting case).
    """
    n_total = cfg.param_counts()["total"]
    n_active = cfg.active_param_counts()
    w_bytes = n_total * BYTES_PER_PARAM

    if kind == "train":
        opt_bytes = n_total * BYTES_PER_OPT
        footprint = w_bytes + opt_bytes
        reads = w_bytes + opt_bytes
        writes = w_bytes + opt_bytes
    elif kind == "decode":
        kv_token = _kv_bytes_per_token(cfg)
        kv_bytes = kv_token * global_batch * max(seq_len, 1)
        footprint = w_bytes + kv_bytes
        reads = n_active * BYTES_PER_PARAM + kv_bytes
        writes = kv_token * global_batch
    else:
        raise ValueError(kind)

    return WorkloadProfile(
        name=f"{cfg.name}/{kind}",
        footprint_bytes=int(footprint),
        iter_period_s=step_time_s,
        read_bytes_per_iter=float(reads),
        write_bytes_per_iter=float(writes),
        regular=True,
        row_utilization=row_utilization,
    )


def _kv_bytes_per_token(cfg: ModelConfig) -> int:
    """Per-token recurrent/KV state bytes across the stack."""
    total = 0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "global":
            total += 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        elif kind == "local":
            # bounded window: amortized per-token cost is the same
            # write traffic; reads bounded by the window
            total += 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        # ssm / rglru carry O(1) state: no per-token growth
    return total
