"""Page-access traces: the serving loop's measured access stream.

:mod:`repro.core.refresh_sim` originally consumed only an *analytic*
access model — an affine cursor sweeping ``rows_accessed_per_window``
rows derived from a :class:`repro.core.workload.WorkloadProfile`.  This
module is the measured counterpart: the engine
(:class:`repro.serve.engine.ServeEngine`) records, per decode step,
exactly which physical pages of each pool stream it read or wrote
(KV sweeps + appends, state reads/writes, page-in/out moves) into a
:class:`PageAccessTrace` hanging off its telemetry sink; a
:class:`repro.core.placement.Placement` then converts page ids into
DRAM rows, yielding the per-window touched-rows bitmaps that
:func:`repro.core.refresh_sim.simulate_trace` replays.

Token *values* never enter the trace — page accesses are determined by
context lengths and scheduling alone, so a trace from fixed prompts is
deterministic and its derived refresh counts are pinnable.

Prefix sharing (PR 10) needs no special cases here, which is the
point: the trace records *physical* page ids, and
:meth:`PageAccessTrace.record_step` dedups them per step — so when N
slots' block tables reference one refcounted shared page
(:mod:`repro.serve.paging`), the step touches that page ONCE.  The
shared-page saving therefore lands exactly where the paper's energy
model looks: fewer distinct pages per step -> fewer DRAM rows per
retention window under any placement -> fewer refresh-triggered-
computation opportunities billed.  :meth:`PageAccessTrace.step_page_counts`
exposes the per-stream touch totals a shared serve can be compared to
its unshared twin on (``benchmarks/serve_sweep.py``'s prefix column).

:func:`affine_masks` generates the bitmap the affine cursor would have
produced, giving the equivalence bridge: ``simulate_trace`` on
``affine_masks(...)`` must reproduce ``simulate(...)`` exactly (see
``tests/test_trace_sim.py``).

(The LM phase profiles that used to live here moved to
:func:`repro.core.workload.lm_workload`, next to the profile dataclass
they build.)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.placement import Placement

__all__ = ["PageAccessTrace", "TraceStep", "affine_masks", "window_masks"]


@dataclasses.dataclass(frozen=True)
class TraceStep:
    """One decode step's page touches.

    ``accesses`` maps stream index -> the (sorted, deduplicated) page
    ids the step read or wrote in that stream; ``param_read`` marks a
    step that re-streamed the resident weights (every real decode step
    does — False only for bookkeeping flushes like end-of-serve
    page-out records).
    """

    accesses: Tuple[Tuple[int, Tuple[int, ...]], ...]
    param_read: bool = True


class PageAccessTrace:
    """Append-only per-step page-access log for one serve() call.

    Stream indices refer to ``stream_names`` (the page table's
    :meth:`~repro.serve.paging.PageTable.stream_names` order); the
    engine validates the binding before recording.
    """

    def __init__(self, stream_names: Sequence[str]):
        self.stream_names: Tuple[str, ...] = tuple(stream_names)
        self.steps: list[TraceStep] = []

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def record_step(self, accesses, *, param_read: bool = True) -> None:
        """Record one step; ``accesses`` is {stream_idx: iterable of page
        ids} or an iterable of (stream_idx, page_ids) pairs."""
        if hasattr(accesses, "items"):
            accesses = accesses.items()
        norm = []
        for si, pids in sorted(accesses):
            si = int(si)
            if not 0 <= si < len(self.stream_names):
                raise ValueError(
                    f"stream index {si} out of range for streams "
                    f"{self.stream_names}")
            pids = tuple(sorted({int(p) for p in pids}))
            if pids:
                norm.append((si, pids))
        self.steps.append(TraceStep(tuple(norm), bool(param_read)))

    def pages_touched(self) -> Tuple[int, ...]:
        """Distinct pages ever touched, per stream."""
        seen = [set() for _ in self.stream_names]
        for step in self.steps:
            for si, pids in step.accesses:
                seen[si].update(pids)
        return tuple(len(s) for s in seen)

    def step_page_counts(self) -> Tuple[int, ...]:
        """Summed per-step page touches, per stream.

        A page is counted once per step no matter how many slots'
        block tables reference it (physical ids dedup in
        :meth:`record_step`), so under prefix sharing this total
        shrinks relative to an unshared serve of the same workload —
        the measured form of the shared-page traffic saving.
        """
        totals = [0] * len(self.stream_names)
        for step in self.steps:
            for si, pids in step.accesses:
                totals[si] += len(pids)
        return tuple(totals)


def window_masks(trace: PageAccessTrace, placement: Placement, *,
                 steps_per_window: int = 1) -> np.ndarray:
    """Trace × placement -> per-window touched-rows bitmap.

    Returns bool ``[n_windows, spec.n_rows]``; window ``w`` covers trace
    steps ``[w*steps_per_window, (w+1)*steps_per_window)`` (the caller
    picks the step-to-retention-window ratio from measured step time vs
    ``spec.effective_retention_s``; the last window keeps any remainder
    steps).  Weight rows are marked for any window containing a
    ``param_read`` step.
    """
    if tuple(trace.stream_names) != tuple(
            g.name for g in placement.streams):
        raise ValueError(
            f"trace streams {trace.stream_names} do not match placement "
            f"streams {tuple(g.name for g in placement.streams)}")
    if steps_per_window < 1:
        raise ValueError(f"steps_per_window={steps_per_window} must be >= 1")
    n_steps = trace.n_steps
    n_windows = max(1, n_steps // steps_per_window)
    masks = np.zeros((n_windows, placement.spec.n_rows), bool)
    for i, step in enumerate(trace.steps):
        w = min(i // steps_per_window, n_windows - 1)
        if step.param_read:
            placement.touch_params(masks[w])
        for si, pids in step.accesses:
            placement.touch(masks[w], si, pids)
    return masks


def affine_masks(n_rows: int, *, alloc_lo: int, alloc_rows: int,
                 rows_accessed_per_window: int, n_windows: int,
                 ) -> np.ndarray:
    """The affine cursor's touched-rows bitmap, window by window.

    Replicates :func:`repro.core.refresh_sim.simulate`'s access model
    bit-exactly: a cursor starting at ``alloc_lo`` sweeps
    ``rows_accessed_per_window`` consecutive rows (wrapping inside the
    allocation span) each window, then advances modulo
    ``span = max(1, alloc_rows)``.  When the per-window access count
    meets or exceeds the span the whole allocation is touched — the
    saturation case the cursor's modulo arithmetic also lands on.
    """
    if not (0 <= alloc_lo and alloc_lo + alloc_rows <= n_rows):
        raise ValueError(
            f"allocation [{alloc_lo}, {alloc_lo + alloc_rows}) outside "
            f"module of {n_rows} rows")
    span = max(1, alloc_rows)
    acc = max(0, int(rows_accessed_per_window))
    masks = np.zeros((n_windows, n_rows), bool)
    cursor = 0
    for w in range(n_windows):
        if alloc_rows > 0 and acc > 0:
            if acc >= span:
                sel = np.arange(span)
            else:
                sel = (cursor + np.arange(acc)) % span
            masks[w, alloc_lo + sel] = True
        cursor = (cursor + acc) % span
    return masks
