"""DRAM device/module model for the RTC framework.

Faithful to the paper's setting (Section II-A, V): LPDDR4-class devices,
64 ms retention, tREFI = 7.8 us (8192 refresh commands per retention
window), 2 KiB rows, banked organization.  Capacities from 2 Gb chips up
to 64 Gb (Fig. 12 scalability study) and module capacities of 2/4/8 GB
(Section V).

Everything here is *static geometry and timing*; energy coefficients live
in :mod:`repro.core.energy`, policies in :mod:`repro.core.rtc`.
"""
from __future__ import annotations

import dataclasses
import enum
import math

GiB = 1024**3
MiB = 1024**2
KiB = 1024


class TempMode(enum.Enum):
    """Operating temperature regime (Section III): retention halves >85C."""

    NORMAL = "normal"      # 64 ms retention
    EXTENDED = "extended"  # 32 ms retention (>85 C)


@dataclasses.dataclass(frozen=True)
class DRAMSpec:
    """Geometry + timing of one DRAM module as seen by the controller.

    The paper evaluates module capacities of 2/4/8 GB built from 2 Gb
    chips (Section V) and chip densities of 2..64 Gb for the scalability
    study (Fig. 12).  ``capacity_bytes`` is the *module* capacity; the
    row is the refresh granule (all cells on a wordline replenish
    together), so ``n_rows`` is the unit RTC reasons about.
    """

    capacity_bytes: int
    row_bytes: int = 2 * KiB          # Section VI-B: "row size of 2048B"
    n_banks: int = 8                  # LPDDR4: 8 banks per channel
    n_channels: int = 2               # LPDDR4 dual channel
    retention_s: float = 64e-3        # JEDEC: refresh every 64 ms
    trefi_s: float = 7.8e-6           # Section III: one REF per 7.8 us
    trfc_s: float = 280e-9            # refresh command latency
    trc_s: float = 60e-9              # ACT..PRE row cycle
    peak_bw_bytes: float = 25.6e9     # LPDDR4-3200 x64-equivalent module
    temp: TempMode = TempMode.NORMAL

    def __post_init__(self) -> None:
        if self.capacity_bytes % self.row_bytes:
            raise ValueError("capacity must be a whole number of rows")
        if self.capacity_bytes <= 0 or self.row_bytes <= 0:
            raise ValueError("capacity/row size must be positive")

    # ---- derived geometry -------------------------------------------------
    @property
    def effective_retention_s(self) -> float:
        return self.retention_s if self.temp is TempMode.NORMAL else self.retention_s / 2

    @property
    def n_rows(self) -> int:
        """Total rows in the module == N_r of Algorithm 1 (footnote 3)."""
        return self.capacity_bytes // self.row_bytes

    @property
    def rows_per_bank(self) -> int:
        return self.n_rows // (self.n_banks * self.n_channels)

    @property
    def refresh_cmds_per_window(self) -> int:
        """REF commands the controller issues per retention window."""
        return int(round(self.effective_retention_s / self.trefi_s))

    @property
    def rows_per_refresh_cmd(self) -> int:
        """Rows replenished in batch by a single REF command."""
        return max(1, math.ceil(self.n_rows / self.refresh_cmds_per_window))

    @property
    def refresh_rows_per_second(self) -> float:
        """Row-refresh rate required for integrity: N_r per retention."""
        return self.n_rows / self.effective_retention_s

    def rows_for_bytes(self, n_bytes: int) -> int:
        return math.ceil(n_bytes / self.row_bytes)

    def refresh_duty_cycle(self) -> float:
        """Fraction of time the device is busy refreshing (perf overhead)."""
        return (self.refresh_cmds_per_window * self.trfc_s) / self.effective_retention_s


# Canonical module configurations used throughout the paper's evaluation.
def module(capacity_gb: float, **kw) -> DRAMSpec:
    return DRAMSpec(capacity_bytes=int(capacity_gb * GiB), **kw)


def smallest_fitting_module(footprint_bytes: int, fill: float = 0.95,
                            sizes_gb=(2, 4, 8, 16, 32, 64, 128, 256, 512),
                            **kw) -> DRAMSpec:
    """Smallest canonical module that holds ``footprint_bytes`` at no
    more than ``fill`` occupancy (falls back to the largest size)."""
    for gb in sizes_gb:
        spec = module(gb, **kw)
        if footprint_bytes <= spec.capacity_bytes * fill:
            break
    return spec


MODULE_2GB = module(2)
MODULE_4GB = module(4)
MODULE_8GB = module(8)
EVAL_MODULES = {"2GB": MODULE_2GB, "4GB": MODULE_4GB, "8GB": MODULE_8GB}


def chip(density_gbit: int, **kw) -> DRAMSpec:
    """Single-chip spec for the Fig. 12 density-scaling study (2..64 Gb)."""
    return DRAMSpec(capacity_bytes=int(density_gbit * GiB // 8), **kw)


FIG12_DENSITIES_GBIT = (2, 4, 8, 16, 32, 64)
