"""Algorithm 1 of the paper: the RTT rate-matching schedule.

Given ``N_a`` (rows touched by read/write accesses during one retention
window) and ``N_r`` (rows that must be refreshed during that window ==
all rows of the module, footnote 3), the algorithm emits, for each slot
of the repeating period ``P = N_r / gcd(N_r, N_a)``, an ``xfer`` bit:

* ``xfer = 1`` — the slot is *implicitly* replenished by a coalesced
  read/write transfer (no explicit REF issued);
* ``xfer = 0`` — the slot requires an *explicit* refresh.

The credit-counter formulation is adapted (per the paper) from
rationally-related clock-domain interfaces [Chabloz & Hemani, TVLSI'14].

Three interchangeable implementations are provided and cross-checked by
property tests:

1. :func:`ratematch_ref`    — straight transliteration of Algorithm 1
   (pure Python; the oracle).
2. :func:`ratematch_scan`   — ``jax.lax.scan`` carry formulation, used
   inside jitted simulator code.
3. :func:`ratematch_closed` — closed form.  The credit recurrence is a
   Bresenham / Euclidean-rhythm generator, so with ``na = N_a/g``,
   ``nr = N_r/g`` (``g = gcd``):

       xfer_i = ceil(i * na / nr) - ceil((i-1) * na / nr),  i = 1..P

   i.e. slots are implicit exactly when the running ideal transfer count
   crosses an integer.  This makes the schedule O(1) per slot and
   trivially vectorizable / shardable.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "period",
    "ratematch_ref",
    "ratematch_scan",
    "ratematch_closed",
    "implicit_fraction",
    "coalesced_access_fraction",
    "schedule_stats",
]


def period(n_a: int, n_r: int) -> int:
    """Length of the repeating xfer pattern, ``P = N_r / gcd(N_r, N_a)``."""
    if n_r <= 0:
        raise ValueError("N_r must be positive")
    if n_a < 0:
        raise ValueError("N_a must be non-negative")
    if n_a == 0:
        return 1  # degenerate: every slot is an explicit refresh
    return n_r // math.gcd(n_r, n_a)


def ratematch_ref(n_a: int, n_r: int) -> List[int]:
    """Reference implementation — Algorithm 1, lines 3-16, verbatim.

    Returns the xfer bit for each of the ``P`` slots of one period.
    """
    if n_r <= n_a:
        # Line 3-4: accesses at least as frequent as refreshes -> all
        # refreshes are replaced by implicit transfers.
        return [1] * period(n_a, n_r)
    p = period(n_a, n_r)
    c = n_r                      # line 7: credit starts at N_r
    out: List[int] = []
    for _ in range(p):
        if c > n_r - n_a:        # line 9
            out.append(1)        # line 10: implicit (transfer) slot
            c -= n_r - n_a       # line 11
        else:
            out.append(0)        # line 13: explicit refresh slot
            c += n_a             # line 14
    return out


def ratematch_scan(n_a, n_r, n_steps: int):
    """`lax.scan` formulation emitting ``n_steps`` xfer bits.

    ``n_a``/``n_r`` may be traced scalars; the schedule repeats with its
    natural period automatically because the credit carry is periodic.
    """
    # Credits are bounded by N_r + N_a (< 2^31 for any module we model),
    # so int32 is safe without enabling x64.
    n_a = jnp.asarray(n_a, jnp.int32)
    n_r = jnp.asarray(n_r, jnp.int32)

    def step(c, _):
        implicit = (n_r <= n_a) | (c > n_r - n_a)
        c_next = jnp.where(implicit, c - (n_r - n_a), c + n_a)
        # When N_r <= N_a the branch above would run the credit to -inf;
        # pin it (the xfer output is what matters and is always 1 there).
        c_next = jnp.where(n_r <= n_a, n_r, c_next)
        return c_next, implicit.astype(jnp.int32)

    _, bits = jax.lax.scan(step, n_r, None, length=n_steps)
    return bits


def ratematch_closed(i, n_a: int, n_r: int):
    """Closed-form xfer bit for 1-indexed slot(s) ``i`` (vectorized).

    ``xfer_i = ceil(i*na/nr) - ceil((i-1)*na/nr)`` with reduced na/nr.
    Matches :func:`ratematch_ref` exactly (property-tested).
    """
    if n_r <= n_a:
        return np.ones_like(np.asarray(i), dtype=np.int32)
    g = math.gcd(n_r, n_a) if n_a > 0 else n_r
    na, nr = (n_a // g if n_a else 0), n_r // g
    # int64 host math: i*na can exceed 2^31 for multi-million-row modules.
    i = np.asarray(i, np.int64)
    return (_ceil_div(i * na, nr) - _ceil_div((i - 1) * na, nr)).astype(np.int32)


def _ceil_div(a, b):
    return -(-a // b)


def implicit_fraction(n_a: float, n_r: float) -> float:
    """Fraction of the window's refresh obligations satisfied implicitly.

    == f_c in the energy model: min(1, N_a / N_r).  This is the exact
    density of 1-bits in the Algorithm-1 schedule (na/nr over period P).
    """
    if n_r <= 0:
        return 1.0
    return min(1.0, n_a / n_r)


def coalesced_access_fraction(n_a: float, n_r: float) -> float:
    """Fraction of *accesses* whose row activation doubles as a refresh.

    When N_a <= N_r every access lands on a slot that needed replenishing
    anyway (x_c = 1); past that, only N_r of the N_a accesses carry
    refresh duty: x_c = min(1, N_r / N_a).
    """
    if n_a <= 0:
        return 0.0
    return min(1.0, n_r / n_a)


def schedule_stats(n_a: int, n_r: int) -> Tuple[int, int, int]:
    """(period, implicit_slots, explicit_slots) for one period."""
    bits = ratematch_ref(n_a, n_r)
    ones = int(np.sum(bits))
    return len(bits), ones, len(bits) - ones
