"""Row-granular DRAM allocator.

RTC reasons about DRAM at *row* granularity: PAAR (Full-RTC) refreshes
only ``[start, end)`` row ranges holding live data (Section IV-C2's
bound registers), Mid-RTC's bank-granular PAAR needs to know which banks
are entirely empty (Section IV-B), and the RTT AGU iterates allocated
regions with an affine address function (Section III-C).

Two placement policies, matching the trade-off discussed in the paper:

* ``pack``       — fill rows contiguously from row 0.  Maximizes the
  number of completely-empty banks (best for Mid-RTC PAAR) and yields a
  single tight [lo, hi) bound (best for Full-RTC PAAR).
* ``interleave`` — stripe regions across banks for bank-level
  parallelism / bandwidth (Section III-E maps concurrent applications to
  disjoint banks; a bandwidth-bound single app stripes).

The allocator is deliberately simple (bump allocation, no free): the
paper's workloads allocate once per application launch, which is also
how accelerator runtimes behave.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Tuple

from repro.core.dram import DRAMSpec

__all__ = ["Region", "AllocationMap", "Allocator"]


@dataclasses.dataclass(frozen=True)
class Region:
    """A named, row-aligned allocation.

    ``striped`` regions are interleaved across all banks (their
    ``start_row``/``n_rows`` describe the *logical* row span; physically
    every bank holds a slice), which matters only for Mid-RTC bank
    accounting.
    """

    name: str
    start_row: int
    n_rows: int
    n_bytes: int
    striped: bool = False

    @property
    def end_row(self) -> int:
        return self.start_row + self.n_rows

    def rows(self) -> range:
        return range(self.start_row, self.end_row)


@dataclasses.dataclass
class AllocationMap:
    """All live regions of one application on one module."""

    spec: DRAMSpec
    regions: Dict[str, Region] = dataclasses.field(default_factory=dict)

    # ---- aggregate row accounting ----------------------------------------
    @property
    def allocated_rows(self) -> int:
        return sum(r.n_rows for r in self.regions.values())

    @property
    def allocated_bytes(self) -> int:
        return sum(r.n_bytes for r in self.regions.values())

    @property
    def allocated_fraction(self) -> float:
        return self.allocated_rows / self.spec.n_rows

    def bounds(self) -> Tuple[int, int]:
        """Tightest [lo, hi) row bound covering all regions.

        This is exactly what Full-RTC's two PAAR bound registers hold
        (Fig. 6).  Returns (0, 0) when nothing is allocated.
        """
        if not self.regions:
            return (0, 0)
        lo = min(r.start_row for r in self.regions.values())
        hi = max(r.end_row for r in self.regions.values())
        return lo, hi

    def rows_within_bounds(self) -> int:
        lo, hi = self.bounds()
        return hi - lo

    # ---- bank accounting (Mid-RTC) ---------------------------------------
    def banks_touched(self) -> int:
        """Number of banks with >=1 allocated row (others skip refresh
        entirely under Mid-RTC's bank-granular PAAR)."""
        n_banks = self.spec.n_banks * self.spec.n_channels
        rows_per_bank = self.spec.rows_per_bank
        touched = set()
        for r in self.regions.values():
            if not r.n_rows:
                continue
            if r.striped:
                return n_banks  # interleaved data lands in every bank
            first = r.start_row // rows_per_bank
            last = (r.end_row - 1) // rows_per_bank
            touched.update(range(first, min(last, n_banks - 1) + 1))
        return len(touched)

    def bank_paar_refresh_fraction(self) -> float:
        """Fraction of rows Mid-RTC must still refresh (whole banks)."""
        n_banks = self.spec.n_banks * self.spec.n_channels
        if not self.regions:
            return 0.0
        return self.banks_touched() / n_banks

    def row_paar_refresh_fraction(self) -> float:
        """Fraction of rows Full-RTC must still refresh ([lo, hi) bound)."""
        return self.rows_within_bounds() / self.spec.n_rows


class Allocator:
    """Bump allocator over a module's row space."""

    def __init__(self, spec: DRAMSpec, policy: str = "pack"):
        if policy not in ("pack", "interleave"):
            raise ValueError(f"unknown placement policy: {policy}")
        self.spec = spec
        self.policy = policy
        self._next_row = 0
        self.map = AllocationMap(spec=spec)

    def alloc(self, name: str, n_bytes: int) -> Region:
        if name in self.map.regions:
            raise ValueError(f"region {name!r} already allocated")
        if n_bytes < 0:
            raise ValueError("negative allocation")
        n_rows = self.spec.rows_for_bytes(n_bytes) if n_bytes else 0
        if self._next_row + n_rows > self.spec.n_rows:
            raise MemoryError(
                f"OOM: {name} needs {n_rows} rows, "
                f"{self.spec.n_rows - self._next_row} free"
            )
        region = Region(
            name, self._next_row, n_rows, n_bytes,
            striped=(self.policy == "interleave"),
        )
        self._next_row += n_rows
        self.map.regions[name] = region
        return region

    def alloc_many(self, sizes: Iterable[Tuple[str, int]]) -> AllocationMap:
        for name, n_bytes in sizes:
            self.alloc(name, n_bytes)
        return self.map

    @property
    def free_rows(self) -> int:
        return self.spec.n_rows - self._next_row


def allocate_workload(
    spec: DRAMSpec, sizes: Dict[str, int], policy: str = "pack"
) -> AllocationMap:
    """Convenience: allocate all named byte sizes, return the map."""
    alloc = Allocator(spec, policy=policy)
    return alloc.alloc_many(sorted(sizes.items(), key=lambda kv: -kv[1]))
