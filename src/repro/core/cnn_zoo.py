"""The paper's CNN workloads: AlexNet, LeNet, GoogleNet (Section V/VI).

RTC consumes *phase-level memory profiles*, matching the paper's own
methodology: their in-house simulator [21] emits operation counts
(ACT/RD/WR/PRE traces of a row-stationary Eyeriss-class accelerator [9])
that feed the Rambus energy model.  We reproduce that pipeline with
published layer tables:

* per-layer weight/activation sizes  -> DRAM footprint (what PAAR sees);
* per-frame DRAM traffic under a row-stationary dataflow with a
  *data-locality-exploitation* parameter L (Section VI-A: L=100% means
  each datum is fetched once per frame, L=50% twice) -> row-activation
  rate (what RTT sees).

Anchors from the paper used as ground truth for calibration tests:
  - LeNet memory footprint 1.06 MB (Section III-D, 100x100 input);
  - AlexNet ~60M DRAM accesses/frame on an Eyeriss-class accelerator
    (Section II-B);
  - AlexNet@60fps on a 2 GB module: rows touched per 64 ms retention
    window ~= 44% of all rows (Fig. 10a RTT savings).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from repro.core.dram import MiB

__all__ = ["ConvLayer", "FCLayer", "CNNProfile", "CNN_ZOO", "cnn_profile"]

# Element widths per network, matching the traces the paper feeds the
# Rambus model: AlexNet/GoogleNet use fp32 weights/activations on the
# Eyeriss-class datapath; LeNet runs 8-bit (the MOCHA accelerator [21] is
# compression-aware), which is what makes the paper's stated 1.06 MB
# footprint (Section III-D) arithmetically consistent with the 100x100
# LeNet-5 layer table (~0.96M parameters).
ELEM_BYTES = {"alexnet": 4, "googlenet": 4, "lenet": 1}
BYTES_PER_ELEM = 4  # default (fp32)


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    c_in: int
    c_out: int
    k: int          # kernel size (square)
    h_out: int      # output feature map height
    w_out: int      # output feature map width
    stride: int = 1
    groups: int = 1

    @property
    def weight_elems(self) -> int:
        return (self.c_in // self.groups) * self.c_out * self.k * self.k

    @property
    def out_act_elems(self) -> int:
        return self.c_out * self.h_out * self.w_out

    @property
    def macs(self) -> int:
        return (self.c_in // self.groups) * self.c_out * self.k * self.k * self.h_out * self.w_out


@dataclasses.dataclass(frozen=True)
class FCLayer:
    name: str
    n_in: int
    n_out: int

    @property
    def weight_elems(self) -> int:
        return self.n_in * self.n_out

    @property
    def out_act_elems(self) -> int:
        return self.n_out

    @property
    def macs(self) -> int:
        return self.n_in * self.n_out


@dataclasses.dataclass(frozen=True)
class CNNProfile:
    """Phase-level DRAM profile of one CNN inference pass ("frame")."""

    name: str
    weight_bytes: int          # resident parameter footprint
    peak_act_bytes: int        # resident activation buffer (double-buffered max)
    read_bytes_per_frame: int  # DRAM reads per frame at L = 100%
    write_bytes_per_frame: int
    macs_per_frame: int

    @property
    def footprint_bytes(self) -> int:
        return self.weight_bytes + self.peak_act_bytes

    def traffic_per_frame(self, locality: float = 1.0) -> int:
        """Total DRAM bytes moved per frame.

        ``locality`` is the paper's data-locality-exploitation factor:
        1.0 -> the dataset is read once per frame; 0.5 -> twice.
        """
        if not 0.0 < locality <= 1.0:
            raise ValueError("locality must be in (0, 1]")
        return int(self.read_bytes_per_frame / locality) + self.write_bytes_per_frame


# --------------------------------------------------------------------------
# Layer tables (public configurations).
# --------------------------------------------------------------------------

# AlexNet [Krizhevsky+, NIPS'12] — 224x224x3 input, 1000 classes.
ALEXNET_CONV: List[ConvLayer] = [
    ConvLayer("conv1", 3, 96, 11, 55, 55, stride=4),
    ConvLayer("conv2", 96, 256, 5, 27, 27, groups=2),
    ConvLayer("conv3", 256, 384, 3, 13, 13),
    ConvLayer("conv4", 384, 384, 3, 13, 13, groups=2),
    ConvLayer("conv5", 384, 256, 3, 13, 13, groups=2),
]
ALEXNET_FC: List[FCLayer] = [
    FCLayer("fc6", 256 * 6 * 6, 4096),
    FCLayer("fc7", 4096, 4096),
    FCLayer("fc8", 4096, 1000),
]

# LeNet-5 [LeCun+, 1998] scaled to the paper's 100x100 character-
# recognition input (Section III-D: total footprint 1.06 MB).
LENET_CONV: List[ConvLayer] = [
    ConvLayer("conv1", 1, 6, 5, 96, 96),
    ConvLayer("conv2", 6, 16, 5, 44, 44),
]
LENET_FC: List[FCLayer] = [
    FCLayer("fc3", 16 * 22 * 22, 120),   # dominated by this layer at 100x100
    FCLayer("fc4", 120, 84),
    FCLayer("fc5", 84, 10),
]

# GoogleNet / Inception-v1 [Szegedy+, CVPR'15] — coarse per-stage table.
# (~6.8M conv params; activation-traffic dominated.)
GOOGLENET_CONV: List[ConvLayer] = [
    ConvLayer("conv1", 3, 64, 7, 112, 112, stride=2),
    ConvLayer("conv2_reduce", 64, 64, 1, 56, 56),
    ConvLayer("conv2", 64, 192, 3, 56, 56),
    # Inception stages modeled as fused conv-equivalents (param-exact
    # aggregates of the published inception branch dims).
    ConvLayer("inception_3a_3b", 224, 280, 3, 28, 28),
    ConvLayer("inception_4a_4e", 512, 560, 3, 14, 14),
    ConvLayer("inception_5a_5b", 861, 938, 3, 7, 7),
]
GOOGLENET_FC: List[FCLayer] = [FCLayer("fc", 1024, 1000)]


def _profile(name: str, convs: List[ConvLayer], fcs: List[FCLayer]) -> CNNProfile:
    eb = ELEM_BYTES.get(name, BYTES_PER_ELEM)
    w = eb * (sum(l.weight_elems for l in convs) + sum(l.weight_elems for l in fcs))
    acts = [eb * l.out_act_elems for l in convs] + [eb * l.out_act_elems for l in fcs]
    # Row-stationary accelerator: per layer, read weights once and the
    # input fmap once; write the output fmap once (L = 100%).  The input
    # of layer i is the output of layer i-1.
    reads = w + sum(acts[:-1]) + convs[0].c_in * convs[0].h_out * convs[0].w_out * (
        convs[0].stride ** 2) * eb  # input image
    writes = sum(acts)
    macs = sum(l.macs for l in convs) + sum(l.macs for l in fcs)
    # double-buffered largest adjacent activation pair
    peak_act = max(a + b for a, b in zip(acts, acts[1:])) if len(acts) > 1 else acts[0]
    return CNNProfile(name, w, peak_act, int(reads), int(writes), macs)


def cnn_profile(name: str) -> CNNProfile:
    key = name.lower()
    if key in ("alexnet", "an"):
        return _profile("alexnet", ALEXNET_CONV, ALEXNET_FC)
    if key in ("lenet", "ln"):
        return _profile("lenet", LENET_CONV, LENET_FC)
    if key in ("googlenet", "gn"):
        return _profile("googlenet", GOOGLENET_CONV, GOOGLENET_FC)
    raise KeyError(f"unknown CNN {name!r}")


CNN_ZOO: Dict[str, CNNProfile] = {
    n: cnn_profile(n) for n in ("alexnet", "lenet", "googlenet")
}
