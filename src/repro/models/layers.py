"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

Hand-rolled functional style: every layer is ``init(key, cfg) ->
params`` + ``apply(params, x) -> y`` over plain dict pytrees, which
keeps the sharding rules (``repro.dist.sharding``) a simple map over
param-tree paths and avoids any framework dependency.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.axisenv import constrain

__all__ = [
    "dense_init", "rmsnorm_init", "rmsnorm", "mlp_init", "mlp_apply",
    "rope", "softcap", "embed_init", "causal_conv1d",
]


def causal_conv1d(params, x, state=None, lengths=None):
    """Depthwise causal conv shared by the ssm and rglru blocks.

    x: [b, s, width]; params hold ``conv_w`` [k, width] / ``conv_b``.
    ``state`` ([b, k-1, width]): carried tail for decode; None prefixes
    zeros (prefill).  ``lengths`` ([b] int32): gather the returned tail
    from the last ``k-1`` positions *below* each sequence's real length
    instead of the (possibly right-padded) array tail.  Returns
    (out [b, s, width], new_state [b, k-1, width]).
    """
    k = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i:i + x.shape[1], :] * params["conv_w"][i] for i in range(k)
    ) + params["conv_b"]
    if k <= 1:
        new_state = pad
    elif lengths is None:
        new_state = xp[:, -(k - 1):, :]
    else:
        # xp row (length + j) is input position length-(k-1)+j, or one of
        # the leading zero rows when that position is negative.
        idx = jnp.asarray(lengths, jnp.int32)[:, None] + jnp.arange(k - 1)
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return out, new_state


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (the zoo's default)."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU or plain)
# ---------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d, d_ff), dtype),
         "wo": dense_init(ks[1], (d_ff, d), dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], (d, d_ff), dtype)
    return p


def mlp_apply(params: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = constrain(x @ params["wi"], "B", None, "M")
    if "wg" in params:
        h = act(constrain(x @ params["wg"], "B", None, "M")) * h
    else:
        h = act(h)
    return constrain(h @ params["wo"], "B", None, None)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., seq, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (jnp.tanh(x / cap) * cap).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype) -> dict:
    return {"tok": dense_init(key, (vocab, d), dtype, scale=1.0)}
