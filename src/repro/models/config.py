"""Model configuration: one dataclass drives every architecture.

Each assigned architecture is a :class:`ModelConfig` instance in
``repro.configs.<id>``; per-arch quirks (GeGLU, logit softcaps, QKV
bias, alternating local/global attention, MoE, Mamba, RG-LRU, modality
frontends) are config fields so the whole zoo shares one code path —
which is what lets the 40-cell dry-run sweep be a single driver.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["ModelConfig", "LayerKind"]

# Layer kinds appearing in ``attn_pattern`` (cycled across depth):
#   "global" — full causal attention
#   "local"  — sliding-window causal attention (window_size)
#   "ssm"    — Mamba-1 selective-state-space block (attention-free)
#   "rglru"  — RG-LRU recurrent block (RecurrentGemma)
LayerKind = str


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free archs)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # --- MLP ---------------------------------------------------------------
    mlp_gated: bool = True       # SwiGLU/GeGLU vs plain 2-layer MLP
    mlp_activation: str = "silu"  # silu | gelu
    # --- attention ---------------------------------------------------------
    attn_pattern: Tuple[LayerKind, ...] = ("global",)
    # trailing layers that don't complete a pattern group (e.g.
    # recurrentgemma's published 26 = 8 x (rglru,rglru,local) + 2 rglru);
    # applied after the scanned groups, so the scan body stays small.
    pattern_tail: Tuple[LayerKind, ...] = ()
    window_size: Optional[int] = None
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None   # gemma2 attention-logit softcap
    logit_softcap: Optional[float] = None  # gemma2 final-logit softcap
    rope_theta: float = 10000.0
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # Layout-only transform: store/compute each expert as `s` virtual
    # experts of width d_ff/s.  EXACT for gated MLPs (f-slices are
    # independent through the activation; wo row-blocks sum), and it
    # makes the expert dim divide the model axis (mixtral: 8 experts x
    # split 2 -> 16 on a 16-way mesh), which keeps expert parallelism
    # a clean einsum batch dim through the backward pass.
    moe_virtual_split: int = 1
    # --- SSM (Mamba-1) -----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: Optional[int] = None      # default ceil(d_model/16)
    # --- recurrent (RG-LRU) --------------------------------------------------
    lru_width: Optional[int] = None        # default d_model
    conv1d_width: int = 4
    # --- embeddings / head ---------------------------------------------------
    tie_embeddings: bool = True
    scale_embeddings: bool = False         # gemma: embed * sqrt(d_model)
    # --- modality frontend (vlm/audio): STUB per assignment ------------------
    frontend: Optional[str] = None         # None | "vision" | "audio"
    frontend_tokens: int = 0               # prompt positions fed as embeddings
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    # --- training-shape metadata ----------------------------------------------
    max_seq_len: int = 8192

    # ---- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.n_heads and self.n_heads % max(1, self.n_kv_heads):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if (self.n_layers - len(self.pattern_tail)) % len(self.attn_pattern):
            raise ValueError(
                "n_layers minus tail must be a multiple of the pattern period")
        if self.n_experts and not self.experts_per_token:
            raise ValueError("MoE needs experts_per_token")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def pattern_period(self) -> int:
        return len(self.attn_pattern)

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.pattern_tail)) // self.pattern_period

    @property
    def all_kinds(self) -> Tuple[LayerKind, ...]:
        return tuple(self.attn_pattern) + tuple(self.pattern_tail)

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("ssm", "rglru") for k in self.all_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer keeps an unbounded full-attention cache —
        the gate for the ``long_500k`` shape (see DESIGN.md §5)."""
        return all(k != "global" for k in self.all_kinds)

    def layer_kind(self, layer_idx: int) -> LayerKind:
        grouped = self.n_groups * self.pattern_period
        if layer_idx >= grouped:
            return self.pattern_tail[layer_idx - grouped]
        return self.attn_pattern[layer_idx % self.pattern_period]

    def decode_cache_len(self, kind: LayerKind, max_len: int) -> int:
        """Cache slots one attention layer allocates for decoding.

        THE sizing rule: ``global`` layers append up to ``max_len``
        positions; ``local`` layers keep a ``window_size`` ring.  Both
        the model's cache construction (init/prefill) and the serving
        telemetry's byte accounting call this, so they cannot drift.
        """
        if kind == "local":
            return min(max_len, self.window_size or max_len)
        return max_len

    # ---- parameter accounting (roofline MODEL_FLOPS) ------------------------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.resolved_head_dim
        h, k = self.n_heads, self.n_kv_heads
        counts = {"embed": self.vocab_size * d}
        attn = d * h * hd + 2 * d * k * hd + h * hd * d
        if self.qkv_bias:
            attn += (h + 2 * k) * hd
        mlp_dense = d * self.d_ff * (3 if self.mlp_gated else 2)
        per_kind = {}
        for kind in set(self.attn_pattern):
            if kind in ("global", "local"):
                per_kind[kind] = attn + (
                    self.n_experts * mlp_dense + d * self.n_experts
                    if self.n_experts else mlp_dense
                ) + 2 * d
            elif kind == "ssm":
                di, n, r = self.d_inner, self.ssm_state, self.resolved_dt_rank
                per_kind[kind] = (
                    d * 2 * di + di * self.ssm_conv + di * (r + 2 * n)
                    + r * di + di * n + di + di * d + d
                )
            elif kind == "rglru":
                dl = self.resolved_lru_width
                per_kind[kind] = (
                    2 * d * dl + dl * self.conv1d_width + 2 * dl * dl + dl
                    + dl * d + mlp_dense + 2 * d
                )
        counts["blocks"] = sum(
            per_kind[self.layer_kind(i)] for i in range(self.n_layers)
        )
        counts["final_norm"] = d
        counts["lm_head"] = 0 if self.tie_embeddings else d * self.vocab_size
        counts["total"] = sum(counts.values())
        return counts

    def active_param_counts(self) -> int:
        """Active params per token (== total for dense; routed for MoE)."""
        if not self.n_experts:
            return self.param_counts()["total"]
        full = self.param_counts()["total"]
        d = self.d_model
        mlp_dense = d * self.d_ff * (3 if self.mlp_gated else 2)
        inactive = (self.n_experts - self.experts_per_token) * mlp_dense
        return full - self.n_layers * inactive
