"""Unified decoder-only model covering all 10 assigned architectures.

One block-stack implementation, scanned over depth in *pattern groups*
(the repeating unit of ``cfg.attn_pattern`` — e.g. (local, global) for
gemma2-9b, (rglru, rglru, local) for recurrentgemma) so the HLO stays
O(1) in depth while heterogeneous layer schedules remain expressible.

API (functional, dict pytrees):
    model = TransformerLM(cfg)
    params = model.init(key)                      # or jax.eval_shape
    logits, aux = model.apply(params, tokens)     # train / prefill
    loss = model.loss(params, tokens, labels)
    cache = model.init_cache(batch, max_len)
    logits, cache = model.decode_step(params, cache, token, pos)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.axisenv import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (embed_init, mlp_apply, mlp_init, rmsnorm,
                                 rmsnorm_init, softcap)

__all__ = ["TransformerLM"]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


class TransformerLM:
    """``unroll=True`` replaces the depth ``lax.scan`` with a Python
    loop over groups.  Used by the dry-run analysis pass: XLA's
    HloCostAnalysis visits a while-loop body ONCE regardless of trip
    count, so only the unrolled HLO yields exact per-step FLOPs / bytes
    / collective counts (verified in tests/test_dryrun.py).  The scan
    form keeps compile time O(1) in depth for training/serving and the
    multi-pod compile proof."""

    def __init__(self, cfg: ModelConfig, remat: str = "none",
                 unroll: bool = False):
        if remat not in ("none", "full", "dots"):
            raise ValueError(f"unknown remat policy {remat!r}")
        self.cfg = cfg
        self.remat = remat
        self.unroll = unroll

    # ------------------------------------------------------------------ init
    def _layer_init(self, key, kind: str) -> dict:
        cfg, dt = self.cfg, _dtype(self.cfg)
        ks = jax.random.split(key, 4)
        p = {"ln1": rmsnorm_init(cfg.d_model, dt)}
        if kind in ("global", "local"):
            p["attn"] = attn.attn_init(ks[0], cfg, dt)
            p["ln2"] = rmsnorm_init(cfg.d_model, dt)
            if cfg.n_experts:
                p["moe"] = moe_mod.moe_init(ks[1], cfg, dt)
            else:
                p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.mlp_gated, dt)
        elif kind == "ssm":
            p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dt)
        elif kind == "rglru":
            p["rec"] = rglru_mod.rglru_init(ks[0], cfg, dt)
            p["ln2"] = rmsnorm_init(cfg.d_model, dt)
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                cfg.mlp_gated, dt)
        else:  # pragma: no cover
            raise ValueError(kind)
        return p

    def init(self, key) -> dict:
        cfg, dt = self.cfg, _dtype(self.cfg)
        ke, kb, kh = jax.random.split(key, 3)
        params = {"embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dt)}
        blocks = []
        for pos, kind in enumerate(cfg.attn_pattern):
            per_group = [
                self._layer_init(jax.random.fold_in(kb, g * 31 + pos), kind)
                for g in range(cfg.n_groups)
            ]
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
        params["blocks"] = tuple(blocks)
        if cfg.pattern_tail:
            params["tail"] = tuple(
                self._layer_init(jax.random.fold_in(kb, 7919 + i), kind)
                for i, kind in enumerate(cfg.pattern_tail)
            )
        params["final_norm"] = rmsnorm_init(cfg.d_model, dt)
        if not cfg.tie_embeddings:
            from repro.models.layers import dense_init
            params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_size), dt)
        return params

    def abstract_params(self) -> dict:
        """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
        return jax.eval_shape(
            lambda: self.init(jax.random.key(0))
        )

    # ------------------------------------------------------------- embedding
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"]["tok"][tokens]
        if cfg.scale_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return x

    def _unembed(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["tok"].T
        else:
            logits = x @ params["lm_head"]
        if logits.ndim == 3:
            # vocab-sharded; seq stays sequence-parallel only for real
            # sequences (decode's singleton seq dim must not grab axes)
            stag = "S" if logits.shape[1] > 1 else None
            logits = constrain(logits, "B", stag, "M")
        return softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    # ----------------------------------------------------------- full forward
    def _block_apply(self, kind, p, x, positions):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind in ("global", "local"):
            x = x + attn.attn_apply(p["attn"], cfg, rmsnorm(p["ln1"], x),
                                    positions, kind)
            h = rmsnorm(p["ln2"], x)
            if cfg.n_experts:
                y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
            else:
                y = mlp_apply(p["mlp"], h, cfg.mlp_activation)
            x = x + y
        elif kind == "ssm":
            x = x + ssm_mod.ssm_apply(p["ssm"], cfg, rmsnorm(p["ln1"], x))
        elif kind == "rglru":
            x = x + rglru_mod.rglru_apply(p["rec"], cfg, rmsnorm(p["ln1"], x))
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x),
                              cfg.mlp_activation)
        return x, aux

    def apply(self, params, tokens=None, embeds=None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence forward. Returns (logits f32, aux_loss f32).

        ``embeds`` ([b, s, d]) replaces token embedding for the stub
        modality frontends (vlm/audio input_specs feed precomputed
        patch/frame embeddings, per the assignment).
        """
        x, aux = self.hidden(params, tokens=tokens, embeds=embeds)
        return self._unembed(params, x), aux

    def hidden(self, params, tokens=None, embeds=None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Trunk forward up to (excluding) the unembed.

        Returns (hidden [b, s, d], aux_loss).  Shared by ``apply`` and
        the sequence-chunked CE loss path.
        """
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(_dtype(cfg))
        else:
            x = self._embed(params, tokens)
        x = constrain(x, "B", "S", None)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def group_body(carry, gp):
            x, aux = carry
            for i, kind in enumerate(cfg.attn_pattern):
                x, a = self._block_apply(kind, gp[i], x, positions)
                x = constrain(x, "B", "S", None)
                aux = aux + a
            return (x, aux), None

        if self.remat != "none":
            policy = (jax.checkpoint_policies.checkpoint_dots
                      if self.remat == "dots" else
                      jax.checkpoint_policies.nothing_saveable)
            group_body = jax.checkpoint(group_body, policy=policy,
                                        prevent_cse=self.unroll)

        carry = (x, jnp.zeros((), jnp.float32))
        if self.unroll:
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda l: l[g], params["blocks"])
                carry, _ = group_body(carry, gp)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(group_body, carry, params["blocks"])
        for i, kind in enumerate(cfg.pattern_tail):
            x, a = self._block_apply(kind, params["tail"][i], x, positions)
            x = constrain(x, "B", "S", None)
            aux = aux + a
        return x, aux

    def loss(self, params, tokens=None, labels=None, embeds=None,
             aux_coeff: float = 0.01) -> jnp.ndarray:
        logits, aux = self.apply(params, tokens=tokens, embeds=embeds)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return jnp.mean(nll) + aux_coeff * aux

    # ----------------------------------------------------------------- decode
    def _one_cache(self, kind, batch, max_len, dt):
        cfg = self.cfg
        if kind in ("global", "local"):
            return attn.init_kv_cache(
                cfg, batch, cfg.decode_cache_len(kind, max_len), dt)
        if kind == "ssm":
            return ssm_mod.init_ssm_cache(cfg, batch, dt)
        if kind == "rglru":
            return rglru_mod.init_rglru_cache(cfg, batch, dt)
        raise ValueError(kind)  # pragma: no cover

    def init_cache(self, batch: int, max_len: int) -> dict:
        """{'groups': per-pattern-position caches stacked over groups,
        'tail': per-tail-layer caches}."""
        cfg, dt = self.cfg, _dtype(self.cfg)
        groups = []
        for kind in cfg.attn_pattern:
            c = self._one_cache(kind, batch, max_len, dt)
            groups.append(
                jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.n_groups,) + x.shape
                    ),
                    c,
                )
            )
        tail = tuple(self._one_cache(kind, batch, max_len, dt)
                     for kind in cfg.pattern_tail)
        return {"groups": tuple(groups), "tail": tail}

    def _one_paged_cache(self, kind, batch, max_ctx, page_size, kv_pages, dt,
                         state_pages=None, shards=1):
        cfg = self.cfg
        if kind in ("global", "local"):
            return attn.init_paged_kv_cache(
                cfg, batch, cfg.decode_cache_len(kind, max_ctx),
                page_size, kv_pages, dt, shards=shards)
        n_state = (batch + shards * attn.RESERVED_PAGES
                   if state_pages is None else state_pages)
        if kind == "ssm":
            return ssm_mod.init_paged_ssm_cache(cfg, batch, n_state, dt,
                                                shards=shards)
        if kind == "rglru":
            return rglru_mod.init_paged_rglru_cache(cfg, batch, n_state, dt,
                                                    shards=shards)
        raise ValueError(kind)  # pragma: no cover

    def init_paged_cache(self, batch: int, max_ctx: int, page_size: int,
                         kv_pages: int, state_pages=None,
                         shards: int = 1) -> dict:
        """Paged twin of :meth:`init_cache`: the same {'groups', 'tail'}
        structure, but each attention layer holds a ``kv_pages``-page
        pool (incl. the reserved pages) behind a per-slot block table
        sized for ``max_ctx`` logical positions, and each recurrent
        layer a ``state_pages``-deep state-page pool (default: one page
        per slot plus the reserved pages; a larger extent buys the data
        axes a divisible page dim to shard).  ``shards`` splits every
        pool into that many equal per-device extents, each with its own
        reserved ZERO/DUMP pair, and pins slot ``s`` (its dead-slot DUMP
        target) to extent ``s // (batch/shards)`` — the layout
        :func:`repro.serve.engine.build_decode_step` maps device-locally
        under ``shard_map``.  ``decode_step`` accepts either form
        unchanged; a fresh paged cache decodes bit-identically to a
        fresh ``init_cache(batch, max_ctx)`` once pages are assigned
        (see :class:`repro.serve.paging.PageTable`)."""
        cfg, dt = self.cfg, _dtype(self.cfg)
        groups = []
        for kind in cfg.attn_pattern:
            c = self._one_paged_cache(kind, batch, max_ctx, page_size,
                                      kv_pages, dt, state_pages, shards)
            groups.append(
                jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.n_groups,) + x.shape
                    ),
                    c,
                )
            )
        tail = tuple(self._one_paged_cache(kind, batch, max_ctx, page_size,
                                           kv_pages, dt, state_pages, shards)
                     for kind in cfg.pattern_tail)
        return {"groups": tuple(groups), "tail": tail}

    def _block_prefill(self, kind, p, x, positions, max_len, lengths=None):
        """Full-sequence block forward that also emits the decode cache.

        ``lengths`` ([b] int32): right-padded (length-bucketed) prefill —
        each family freezes/ignores padded positions so rows below
        ``length`` and the emitted cache are bit-identical to an
        unpadded forward (see the per-family prefill docstrings).
        """
        cfg = self.cfg
        if kind in ("global", "local"):
            h, c = attn.attn_prefill(p["attn"], cfg, rmsnorm(p["ln1"], x),
                                     positions, kind,
                                     cfg.decode_cache_len(kind, max_len),
                                     lengths=lengths)
            x = x + h
            hh = rmsnorm(p["ln2"], x)
            if cfg.n_experts:
                # dropless dispatch: prefill must agree with decode,
                # which never capacity-drops (seq = 1).  The static slot
                # bound is the (padded) sequence length; with a token
                # mask the occupancy actually dispatched is the real
                # (unpadded) token count.
                mask = None if lengths is None \
                    else positions < lengths[:, None]
                y, _ = moe_mod.moe_apply(p["moe"], cfg, hh,
                                         capacity=hh.shape[1],
                                         token_mask=mask)
            else:
                y = mlp_apply(p["mlp"], hh, cfg.mlp_activation)
            x = x + y
        elif kind == "ssm":
            h, c = ssm_mod.ssm_prefill(p["ssm"], cfg, rmsnorm(p["ln1"], x),
                                       lengths=lengths)
            x = x + h
        elif kind == "rglru":
            h, c = rglru_mod.rglru_prefill(p["rec"], cfg,
                                           rmsnorm(p["ln1"], x),
                                           lengths=lengths)
            x = x + h
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x),
                              cfg.mlp_activation)
        else:  # pragma: no cover
            raise ValueError(kind)
        return x, c

    def prefill(self, params, tokens, max_len: int, lengths=None):
        """One-shot serving prefill: full-sequence forward + decode cache.

        tokens: [b, s] int32 with positions 0..s-1.  Returns
        (last-position logits [b, vocab] f32, cache) where the cache has
        exactly the ``init_cache(b, max_len)`` structure, positioned so
        ``decode_step(..., pos=s)`` continues the sequence.  Replaces an
        O(s)-dispatch decode-step prefill with ONE lowered forward.

        ``lengths`` ([b] int32): per-sequence real prompt lengths for
        right-padded (length-bucketed) prefill — one executable serves
        every prompt length in a bucket.  Padding cannot perturb the
        result: attention masks padded keys causally and skips their
        cache rows, recurrent (ssm/rglru) state carries through padded
        steps as an exact identity, MoE dispatch excludes padded
        tokens, and the logits/cache hand-off is taken at ``length-1``
        per sequence (``decode_step(..., pos=length)`` continues).  The
        returned logits and every cache row below ``length`` are
        bit-identical to ``prefill(params, tokens[:, :length], max_len)``
        as long as both sides take the same attention core path (padded
        and real length on the same side of the blocked-attention
        threshold, ``2*attention.QBLOCK``).
        """
        cfg = self.cfg
        x = self._embed(params, tokens)
        x = constrain(x, "B", "S", None)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if lengths is not None:
            lengths = jnp.asarray(lengths, jnp.int32)

        def group_body(x, gp):
            cs = []
            for i, kind in enumerate(cfg.attn_pattern):
                x, c = self._block_prefill(kind, gp[i], x, positions, max_len,
                                           lengths=lengths)
                x = constrain(x, "B", "S", None)
                cs.append(c)
            return x, tuple(cs)

        if self.unroll:
            per_group = []
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda l: l[g], params["blocks"])
                x, cs = group_body(x, gp)
                per_group.append(cs)
            gcaches = jax.tree.map(lambda *ls: jnp.stack(ls), *per_group)
        else:
            x, gcaches = jax.lax.scan(group_body, x, params["blocks"])
        tail_caches = []
        for i, kind in enumerate(cfg.pattern_tail):
            x, c = self._block_prefill(kind, params["tail"][i], x, positions,
                                       max_len, lengths=lengths)
            x = constrain(x, "B", "S", None)
            tail_caches.append(c)
        cache = {"groups": gcaches, "tail": tuple(tail_caches)}
        if lengths is None:
            last = x[:, -1:]
        else:
            idx = jnp.clip(lengths - 1, 0, s - 1)[:, None, None]
            last = jnp.take_along_axis(x, idx, axis=1)
        logits = self._unembed(params, last)[:, 0, :]
        return logits, cache

    def _block_decode(self, kind, p, c, x, pos, backend: str = "gather"):
        cfg = self.cfg
        if kind in ("global", "local"):
            h, c = attn.attn_decode(p["attn"], cfg, rmsnorm(p["ln1"], x),
                                    c, pos, kind, backend=backend)
            x = x + h
            hh = rmsnorm(p["ln2"], x)
            if cfg.n_experts:
                y, _ = moe_mod.moe_apply(p["moe"], cfg, hh)
            else:
                y = mlp_apply(p["mlp"], hh, cfg.mlp_activation)
            x = x + y
        elif kind == "ssm":
            h, c = ssm_mod.ssm_decode(p["ssm"], cfg, rmsnorm(p["ln1"], x), c)
            x = x + h
        elif kind == "rglru":
            h, c = rglru_mod.rglru_decode(p["rec"], cfg, rmsnorm(p["ln1"], x), c)
            x = x + h
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x),
                              cfg.mlp_activation)
        return x, c

    def decode_step(self, params, cache, token, pos,
                    decode_backend: str = "gather"):
        """token: [b] int32 (or [b, d] embeds); pos: [] int32, or [b]
        int32 for per-slot positions (continuous batching: each batch
        slot decodes its own sequence offset).

        ``decode_backend``: attention path for paged caches —
        ``"gather"`` (materialize the logical view; bit-identical to a
        contiguous cache) or ``"pallas_paged"`` (the block-table Pallas
        kernel of :mod:`repro.kernels.paged_attention`; no gather).

        Returns (logits [b, vocab] f32, new_cache).
        """
        cfg = self.cfg
        if token.ndim == 2:  # frontend embedding
            x = token[:, None, :].astype(_dtype(cfg))
        else:
            x = self._embed(params, token[:, None])

        def body(x, inputs):
            gp, gc = inputs
            new_cs = []
            for i, kind in enumerate(cfg.attn_pattern):
                x, nc = self._block_decode(kind, gp[i], gc[i], x, pos,
                                           backend=decode_backend)
                new_cs.append(nc)
            return x, tuple(new_cs)

        gcache = cache["groups"]
        if self.unroll:
            new_groups = []
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda l: l[g], params["blocks"])
                gc = jax.tree.map(lambda l: l[g], gcache)
                x, nc = body(x, (gp, gc))
                new_groups.append(nc)
            new_gcache = jax.tree.map(
                lambda *leaves: jnp.stack(leaves), *new_groups)
        else:
            x, new_gcache = jax.lax.scan(body, x, (params["blocks"], gcache))
        new_tail = []
        for i, kind in enumerate(cfg.pattern_tail):
            x, nc = self._block_decode(kind, params["tail"][i],
                                       cache["tail"][i], x, pos,
                                       backend=decode_backend)
            new_tail.append(nc)
        new_cache = {"groups": new_gcache, "tail": tuple(new_tail)}
        return self._unembed(params, x)[:, 0, :], new_cache
