"""RG-LRU recurrent block (RecurrentGemma-2b / Griffin).

Griffin's recurrent block: parallel (x, gate) projections; temporal
conv1d on x; Real-Gated LRU

    r_t = sigmoid(W_a y_t + b_a)         (recurrence gate)
    i_t = sigmoid(W_x y_t + b_x)         (input gate)
    a_t = exp(c * softplus(L_a) * r_t * log(a_base))  -> a_t = a^(c r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

then h is gated by GeLU(gate) and projected out.  The linear recurrence
is diagonal, so train/prefill uses ``associative_scan`` over the
sequence (state [b, s, lru_width] — no state blowup) and decode is an
O(1) update.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.dist.axisenv import constrain
from repro.models.config import ModelConfig
from repro.models.layers import causal_conv1d, dense_init

__all__ = ["rglru_init", "rglru_apply", "rglru_prefill", "rglru_decode",
           "RGLRUCache", "init_rglru_cache",
           "PagedRGLRUCache", "init_paged_rglru_cache"]

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def rglru_init(key, cfg: ModelConfig, dtype) -> dict:
    d, dl = cfg.d_model, cfg.resolved_lru_width
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], (d, dl), dtype),
        "wgate": dense_init(ks[1], (d, dl), dtype),
        "conv_w": dense_init(ks[2], (cfg.conv1d_width, dl), dtype, scale=0.5),
        "conv_b": jnp.zeros((dl,), dtype),
        "w_a": dense_init(ks[3], (dl, dl), dtype),
        "b_a": jnp.zeros((dl,), dtype),
        "w_i": dense_init(ks[4], (dl, dl), dtype),
        "b_i": jnp.zeros((dl,), dtype),
        # a_base in (0.9, 0.999): parametrized via softplus-logit
        "a_param": jnp.full((dl,), 0.7, jnp.float32),
        "out_proj": dense_init(ks[5], (dl, d), dtype),
    }


def _gates(params, y):
    r = jax.nn.sigmoid(y @ params["w_a"] + params["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(y @ params["w_i"] + params["b_i"]).astype(jnp.float32)
    log_a_base = -_C * jax.nn.softplus(params["a_param"])  # < 0
    a = jnp.exp(log_a_base * r)                            # in (0, 1)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    x_in = beta * (i * y.astype(jnp.float32))
    return a, x_in


def rglru_apply(params, cfg: ModelConfig, x):
    """Full-sequence recurrent block. x: [b, s, d] -> [b, s, d]."""
    y, _ = rglru_prefill(params, cfg, x)
    return y


def rglru_prefill(params, cfg: ModelConfig, x, lengths=None):
    """Full-sequence recurrent block that also returns the decode cache.

    The associative scan already materializes the hidden state at every
    position; the cache is simply its last slice plus the conv tail, so
    serving prefill costs the same one forward as training.

    ``lengths`` ([b] int32): right-padded (length-bucketed) prefill.
    Padded steps become exact recurrence identities (a=1, input 0) and
    the cached state/conv tail come from each sequence's real last
    token — ``associative_scan`` prefixes are built from left-aligned
    trees that depend only on the index, so every row below ``length``
    (and the cache) is bit-identical to the unpadded forward.
    """
    y = constrain(x @ params["wx"], "B", None, "M")
    gate = constrain(x @ params["wgate"], "B", None, "M")
    y, conv_state = causal_conv1d(params, y, lengths=lengths)
    a, x_in = _gates(params, y)
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        m = (jnp.arange(x.shape[1])[None, :] < lengths[:, None])[..., None]
        a = jnp.where(m, a, 1.0)
        x_in = jnp.where(m, x_in, 0.0)

    def combine(e1, e2):
        a1, h1 = e1
        a2, h2 = e2
        return a2 * a1, a2 * h1 + h2

    _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    if lengths is None:
        h_out = h[:, -1]
    else:
        idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)[:, None, None]
        h_out = jnp.take_along_axis(h, idx, axis=1)[:, 0]
    out = h.astype(x.dtype) * jax.nn.gelu(gate)
    return out @ params["out_proj"], RGLRUCache(conv=conv_state, h=h_out)


class RGLRUCache(NamedTuple):
    conv: jnp.ndarray   # [b, k-1, dl]
    h: jnp.ndarray      # [b, dl] f32


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> RGLRUCache:
    dl = cfg.resolved_lru_width
    return RGLRUCache(
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, dl), dtype),
        h=jnp.zeros((batch, dl), jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class PagedRGLRUCache:
    """Page-pool mirror of :class:`RGLRUCache` — see
    :class:`repro.models.ssm.PagedSSMCache` for the state-page model."""

    conv_p: jnp.ndarray   # [n_state_pages, k-1, dl]
    h_p: jnp.ndarray      # [n_state_pages, dl] f32
    block: jnp.ndarray    # [b] int32 state-page ids


jax.tree_util.register_dataclass(
    PagedRGLRUCache, data_fields=("conv_p", "h_p", "block"), meta_fields=())


def init_paged_rglru_cache(cfg: ModelConfig, batch: int, n_pages: int,
                           dtype, shards: int = 1) -> PagedRGLRUCache:
    from repro.models.attention import _shard_dump_ids
    dl = cfg.resolved_lru_width
    return PagedRGLRUCache(
        conv_p=jnp.zeros((n_pages, cfg.conv1d_width - 1, dl), dtype),
        h_p=jnp.zeros((n_pages, dl), jnp.float32),
        block=_shard_dump_ids(batch, n_pages, shards),
    )


def rglru_decode(params, cfg: ModelConfig, x, cache):
    """One-token decode. x: [b, 1, d].  ``cache`` is a contiguous
    :class:`RGLRUCache` or a :class:`PagedRGLRUCache` (gather →
    identical update → scatter back)."""
    paged = isinstance(cache, PagedRGLRUCache)
    conv = cache.conv_p[cache.block] if paged else cache.conv
    h0 = cache.h_p[cache.block] if paged else cache.h
    y = x @ params["wx"]
    gate = x @ params["wgate"]
    y, conv_state = causal_conv1d(params, y, conv)
    a, x_in = _gates(params, y)
    h = a[:, 0] * h0 + x_in[:, 0]
    out = h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate)
    if paged:
        new_cache = dataclasses.replace(
            cache,
            conv_p=cache.conv_p.at[cache.block].set(conv_state),
            h_p=cache.h_p.at[cache.block].set(h))
    else:
        new_cache = RGLRUCache(conv=conv_state, h=h)
    return out @ params["out_proj"], new_cache
