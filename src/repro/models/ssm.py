"""Mamba-1 selective state-space block (falcon-mamba-7b).

Faithful Mamba-1 structure: in_proj -> (x, z); depthwise causal conv1d;
input-dependent (selective) Delta/B/C; diagonal SSM scan
``h_t = exp(Delta*A) h_{t-1} + Delta*B x_t``, ``y = C.h + D x``;
SiLU-gated output projection.

Scan strategy (TPU-adapted, see DESIGN.md):
  * train/prefill — ``lax.scan`` over sequence *chunks*, with a
    parallel ``associative_scan`` inside each chunk: the materialized
    state tensor is [b, chunk, d_inner, ssm_state] instead of the
    O(seq) full tensor, trading O(seq/chunk) sequential steps for a
    VMEM/HBM-feasible working set.
  * decode — O(1) recurrence on the carried (conv_state, ssm_state).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.dist.axisenv import constrain
from repro.models.config import ModelConfig
from repro.models.layers import causal_conv1d, dense_init

__all__ = ["ssm_init", "ssm_apply", "ssm_prefill", "ssm_decode", "SSMCache",
           "init_ssm_cache", "PagedSSMCache", "init_paged_ssm_cache"]

CHUNK = 128  # sequence chunk for the hybrid scan


def ssm_init(key, cfg: ModelConfig, dtype) -> dict:
    d, di, nst, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A (negative real spectrum).
    a_init = jnp.tile(jnp.arange(1, nst + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, r + 2 * nst), dtype),
        "dt_proj": dense_init(ks[3], (r, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(~0.01)
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _ssm_inner(params, cfg: ModelConfig, xc, h0, mask=None, capture=None):
    """One chunk of the selective scan.

    xc: [b, c, di] conv+silu output; h0: [b, di, n] carried state.
    Returns (y: [b, c, di], h: [b, di, n]).

    ``mask`` ([b, c] bool): False positions become exact scan
    identities (a=1, bx=0) so the recurrent state carries through
    padded steps unperturbed.  ``capture`` ([b] int32, requires mask):
    additionally return the state at that chunk-local index (clamped;
    select validity at the caller) — ``associative_scan`` builds each
    prefix from a left-aligned tree that depends only on the index, so
    the captured state is bit-identical to an unpadded scan ending
    there.
    """
    b, c, di = xc.shape
    nst = cfg.ssm_state
    r = cfg.resolved_dt_rank
    dbc = xc @ params["x_proj"]                                  # [b,c,r+2n]
    dt = jax.nn.softplus(
        dbc[..., :r] @ params["dt_proj"] + params["dt_bias"]
    ).astype(jnp.float32)                                        # [b,c,di]
    B = dbc[..., r:r + nst].astype(jnp.float32)                  # [b,c,n]
    C = dbc[..., r + nst:].astype(jnp.float32)                   # [b,c,n]
    A = -jnp.exp(params["A_log"])                                # [di,n]

    a = jnp.exp(dt[..., None] * A)                               # [b,c,di,n]
    bx = (dt * xc.astype(jnp.float32))[..., None] * B[:, :, None, :]
    if mask is not None:
        m = mask[..., None, None]
        a = jnp.where(m, a, 1.0)
        bx = jnp.where(m, bx, 0.0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    # Fold the carried state into the first element, then parallel-scan.
    bx = bx.at[:, 0].add(a[:, 0] * h0)
    acc_a, acc_b = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = jnp.einsum("bcdn,bcn->bcd", acc_b, C)
    y = y + params["D"] * xc.astype(jnp.float32)
    if capture is None:
        return y.astype(xc.dtype), acc_b[:, -1]
    idx = jnp.clip(capture, 0, c - 1)[:, None, None, None]
    h_cap = jnp.take_along_axis(acc_b, idx, axis=1)[:, 0]
    return y.astype(xc.dtype), acc_b[:, -1], h_cap


def ssm_apply(params, cfg: ModelConfig, x):
    """Full-sequence Mamba block. x: [b, s, d] -> [b, s, d]."""
    y, _ = ssm_prefill(params, cfg, x)
    return y


def ssm_prefill(params, cfg: ModelConfig, x, lengths=None):
    """Full-sequence Mamba block that also returns the decode cache.

    Same chunked hybrid scan as training, generalized to arbitrary
    lengths (full chunks via ``lax.scan``, a shorter remainder chunk
    processed once).  Returns (y [b, s, d], :class:`SSMCache`)
    positioned after the last prompt token.

    ``lengths`` ([b] int32): right-padded (length-bucketed) prefill.
    Padded steps become exact scan identities — the recurrent state
    carries through unperturbed — and the cached state/conv tail are
    taken at each sequence's real last token, so the cache is
    bit-identical to an unpadded prefill of the same prompt (chunk
    boundaries land at the same multiples of ``CHUNK`` either way).
    """
    b, s, d = x.shape
    di = cfg.d_inner
    xz = constrain(x @ params["in_proj"], "B", None, "M")
    xin, z = xz[..., :di], xz[..., di:]
    xc, conv_state = causal_conv1d(params, xin, lengths=lengths)
    xc = jax.nn.silu(xc)

    mask = None
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        mask = jnp.arange(s)[None, :] < lengths[:, None]

    chunk = min(CHUNK, s)
    n_full = s // chunk
    h = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    h_cap = h                     # state at position length-1 (masked mode)
    ys = []
    if n_full:
        xcs = xc[:, :n_full * chunk].reshape(b, n_full, chunk, di).swapaxes(0, 1)
        if mask is None:
            def step(h, xchunk):
                y, h_next = _ssm_inner(params, cfg, xchunk, h)
                return h_next, y

            h, yfull = jax.lax.scan(step, h, xcs)
        else:
            ms = mask[:, :n_full * chunk].reshape(b, n_full, chunk).swapaxes(0, 1)
            locs = lengths[None, :] - 1 - jnp.arange(n_full)[:, None] * chunk

            def step(carry, inp):
                h, h_cap = carry
                xchunk, mchunk, loc = inp
                y, h_next, cap = _ssm_inner(params, cfg, xchunk, h,
                                            mask=mchunk, capture=loc)
                hit = ((loc >= 0) & (loc < chunk))[:, None, None]
                return (h_next, jnp.where(hit, cap, h_cap)), y

            (h, h_cap), yfull = jax.lax.scan(step, (h, h_cap), (xcs, ms, locs))
        ys.append(yfull.swapaxes(0, 1).reshape(b, n_full * chunk, di))
    if s - n_full * chunk:
        xr = xc[:, n_full * chunk:]
        if mask is None:
            y_rem, h = _ssm_inner(params, cfg, xr, h)
        else:
            loc = lengths - 1 - n_full * chunk
            y_rem, h, cap = _ssm_inner(params, cfg, xr, h,
                                       mask=mask[:, n_full * chunk:],
                                       capture=loc)
            hit = ((loc >= 0) & (loc < xr.shape[1]))[:, None, None]
            h_cap = jnp.where(hit, cap, h_cap)
        ys.append(y_rem)
    y = ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=1)
    y = y * jax.nn.silu(z)
    h_out = h if mask is None else h_cap
    return y @ params["out_proj"], SSMCache(conv=conv_state, h=h_out)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
class SSMCache(NamedTuple):
    conv: jnp.ndarray   # [b, k-1, di]
    h: jnp.ndarray      # [b, di, n] f32


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    di = cfg.d_inner
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class PagedSSMCache:
    """Page-pool mirror of :class:`SSMCache` for the serving page table.

    Each batch slot's O(1) recurrent state (conv tail + hidden state) is
    one *state page* in a shared pool, indirected through ``block`` —
    the same allocate-on-admit / free-on-retire / offload-on-preempt
    lifecycle as KV pages (:class:`repro.models.attention.PagedKVCache`),
    so every architecture family serves through one
    :class:`repro.serve.paging.PageTable`.  Decode gathers the state,
    runs the exact contiguous update, and scatters it back, so paged and
    contiguous decode are bit-identical.
    """

    conv_p: jnp.ndarray   # [n_state_pages, k-1, di]
    h_p: jnp.ndarray      # [n_state_pages, di, n] f32
    block: jnp.ndarray    # [b] int32 state-page ids


jax.tree_util.register_dataclass(
    PagedSSMCache, data_fields=("conv_p", "h_p", "block"), meta_fields=())


def init_paged_ssm_cache(cfg: ModelConfig, batch: int, n_pages: int,
                         dtype, shards: int = 1) -> PagedSSMCache:
    from repro.models.attention import _shard_dump_ids
    di = cfg.d_inner
    return PagedSSMCache(
        conv_p=jnp.zeros((n_pages, cfg.ssm_conv - 1, di), dtype),
        h_p=jnp.zeros((n_pages, di, cfg.ssm_state), jnp.float32),
        block=_shard_dump_ids(batch, n_pages, shards),
    )


def ssm_decode(params, cfg: ModelConfig, x, cache):
    """One-token decode. x: [b, 1, d].  ``cache`` is a contiguous
    :class:`SSMCache` or a :class:`PagedSSMCache` (gather → identical
    update → scatter back)."""
    paged = isinstance(cache, PagedSSMCache)
    conv = cache.conv_p[cache.block] if paged else cache.conv
    h0 = cache.h_p[cache.block] if paged else cache.h
    di = cfg.d_inner
    xz = x @ params["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xc, conv_state = causal_conv1d(params, xin, conv)
    xc = jax.nn.silu(xc)
    y, h = _ssm_inner(params, cfg, xc, h0)
    y = y * jax.nn.silu(z)
    if paged:
        new_cache = dataclasses.replace(
            cache,
            conv_p=cache.conv_p.at[cache.block].set(conv_state),
            h_p=cache.h_p.at[cache.block].set(h))
    else:
        new_cache = SSMCache(conv=conv_state, h=h)
    return y @ params["out_proj"], new_cache
