"""Model zoo: one unified decoder-only stack covering all 10 assigned
architectures, with serving-grade cache semantics built in.

``config.py`` is the single dataclass every architecture is an instance
of (attention pattern mix, GQA widths, softcap, MoE routing, SSM/RG-LRU
recurrence, modality frontends); ``transformer.py`` assembles it into
``TransformerLM`` with three entry points the serving stack depends on:
``__call__`` (teacher forcing), ``prefill`` (one lowered full-sequence
forward that also materializes the decode cache, bit-identical under
right padding via ``lengths=`` masking), and ``decode_step`` (per-slot
positions, vector ``pos``).

Layer families: ``attention.py`` (GQA/MQA/MHA, causal + sliding-window
rings, softcap, plus the contiguous AND paged KV caches — the paged
path gathers pages into the exact contiguous layout so both are
bit-identical), ``ssm.py`` (Mamba-1 selective scan with state pages),
``rglru.py`` (RG-LRU / Griffin recurrence), ``moe.py`` (dropless top-k
routing with capacity override for prefill), ``layers.py`` (norms,
RoPE, MLPs, embeddings), ``frontends.py`` (vision/audio modality stubs
that keep the multimodal configs servable).

The design rule throughout: every cache-touching op takes both the
contiguous and the paged representation and must produce bitwise-equal
results (pinned across all architectures in
``tests/test_paged_cache.py``) — residency policy lives in
:mod:`repro.serve.paging`, never in the model code.
"""
