"""Attention: GQA/MQA/MHA, causal + sliding-window, softcap, QKV bias.

``attend_full`` is the reference path used for training/prefill and for
the dry-run (on a real TPU the Pallas flash kernel in
``repro.kernels.flash_attention`` substitutes via ``use_kernel=True``;
both are validated against each other in the kernel test sweep).
``decode_attend`` consumes a KV cache for single-token decoding.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.axisenv import constrain, current_env
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rope, softcap

__all__ = ["attn_init", "attn_apply", "attn_prefill", "attn_decode",
           "KVCache", "init_kv_cache",
           "PagedKVCache", "init_paged_kv_cache",
           "ZERO_PAGE", "DUMP_PAGE", "RESERVED_PAGES"]


def attn_init(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, k * hd), dtype),
        "wv": dense_init(ks[2], (d, k * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p |= {"bq": jnp.zeros((h * hd,), dtype),
              "bk": jnp.zeros((k * hd,), dtype),
              "bv": jnp.zeros((k * hd,), dtype)}
    return p


def _project_qkv(params, cfg: ModelConfig, x):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def _mask(s_q: int, s_kv: int, offset, local_window: Optional[int]):
    """Causal (+ optional sliding window) mask. offset = kv_len - q_len."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_kv)[None, :]
    m = kj <= qi
    if local_window is not None:
        m &= kj > qi - local_window
    return m


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: [b,sq,h,hd]; k,v: [b,skv,kvh,hd] — grouped-query attention.

    K/V are expanded to the full query-head count so the whole
    computation shards cleanly on the head axis ("M"); the explicit
    constraints prevent GSPMD from replicating the O(s^2) score tensor
    across the GQA head reshape (which it otherwise does — see the
    §Perf log entry on the first smollm dry-run).  The Pallas flash
    kernel performs the same computation without materializing the
    expanded K/V on real TPUs.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    # Heads shard on the model axis even when h < axis (GSPMD pads; the
    # idle-device cost shows up in the roofline and is a per-arch §Perf
    # note).  Leaving attention unconstrained lets GSPMD replicate the
    # O(s^2) score tensors — measured 3x worse peak memory on smollm.
    q = constrain(q, "B", None, "M", None)
    k = constrain(k, "B", None, "M", None)
    v = constrain(v, "B", None, "M", None)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    logits = constrain(logits, "B", "M", None, None)
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h * hd)


# Query-block size for the chunked (flash-style) path; sequences at or
# below 2*QBLOCK use the direct path.
QBLOCK = 1024


def _attend_causal(q, k, v, cfg: ModelConfig, window: Optional[int]):
    """Blocked causal (+ optional sliding-window) attention core.

    Long sequences use a *blocked* computation: query blocks are
    processed against only their causally (and window-) reachable key
    range with static slice bounds, so the materialized score tensor is
    O(s * QBLOCK) instead of O(s^2) and no FLOPs are spent on fully
    masked blocks — the pure-JAX mirror of the Pallas flash kernel's
    tiling (which substitutes on real TPUs).
    """
    s = q.shape[1]
    if s <= 2 * QBLOCK or s % QBLOCK:
        mask = _mask(s, s, 0, window)
        return _sdpa(q, k, v, mask, cfg)
    outs = []
    for qb in range(s // QBLOCK):
        qs, qe = qb * QBLOCK, (qb + 1) * QBLOCK
        if window is not None:
            ks = max(0, ((qs - window) // QBLOCK) * QBLOCK)
        else:
            ks = 0
        kslice = k[:, ks:qe]
        vslice = v[:, ks:qe]
        mask = _mask(QBLOCK, qe - ks, qs - ks, window)
        outs.append(_sdpa(q[:, qs:qe], kslice, vslice, mask, cfg))
    return jnp.concatenate(outs, axis=1)


def attn_apply(params, cfg: ModelConfig, x, positions, kind: str):
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(params, cfg, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    window = cfg.window_size if kind == "local" else None
    out = _attend_causal(q, k, v, cfg, window)
    return out @ params["wo"]


def attn_prefill(params, cfg: ModelConfig, x, positions, kind: str,
                 cache_len: int, lengths=None):
    """Full-sequence attention that also materializes the decode cache.

    One forward over the whole prompt (same blocked core as
    ``attn_apply``) whose post-RoPE K/V land in a fresh ring/append
    cache of ``cache_len`` slots, ready for ``attn_decode`` to continue
    from position ``s``.  Prompts longer than the cache keep only the
    last ``cache_len`` positions (the only ones a ring buffer would
    retain), at their ring slots.

    ``lengths`` ([b] int32): per-sequence real prompt lengths for
    right-padded (length-bucketed) prefill.  The causal mask already
    keeps padded keys out of every valid query row, so the attention
    output below ``length`` is bit-identical to the unpadded forward;
    the cache scatter additionally drops rows at positions >=
    ``length`` (and below the ring horizon), leaving them zero exactly
    as ``init_kv_cache`` would.
    """
    q, k, v = _project_qkv(params, cfg, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    window = cfg.window_size if kind == "local" else None
    out = _attend_causal(q, k, v, cfg, window)

    s = x.shape[1]
    shape = (x.shape[0], cache_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    if lengths is None:
        keep = min(s, cache_len)
        slots = jnp.arange(s - keep, s) % cache_len
        ck = jnp.zeros(shape, k.dtype).at[:, slots].set(k[:, -keep:])
        cv = jnp.zeros(shape, v.dtype).at[:, slots].set(v[:, -keep:])
        cache = KVCache(ck, cv, jnp.asarray(keep, jnp.int32))
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
        p = jnp.arange(s)[None, :]
        live = (p < lengths[:, None]) & (p >= lengths[:, None] - cache_len)
        # dead rows scatter into a dump slot past the cache and are
        # sliced off; live slots are unique, so `set` is deterministic.
        slots = jnp.where(live, p % cache_len, cache_len)

        def scatter(rows, slots_b):
            buf = jnp.zeros((cache_len + 1,) + rows.shape[1:], rows.dtype)
            return buf.at[slots_b].set(rows)[:cache_len]

        ck = jax.vmap(scatter)(k, slots)
        cv = jax.vmap(scatter)(v, slots)
        keep = jnp.minimum(jnp.max(lengths), cache_len).astype(jnp.int32)
        cache = KVCache(ck, cv, keep)
    return out @ params["wo"], cache


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jnp.ndarray        # [b, cache_len, kv_heads, head_dim]
    v: jnp.ndarray
    length: jnp.ndarray   # [] int32 — tokens currently valid


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> KVCache:
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (batch, cache_len, kvh, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


# Reserved pool pages of every paged cache (KV and recurrent-state):
#   page 0 — ZERO: never written; block entries of a live slot's not-yet-
#            allocated logical pages point here, so gathers read exact
#            zeros (bit-identical to a fresh contiguous cache row).
#   page 1 — DUMP: write sink; block entries of *dead* (unoccupied) batch
#            slots point here so their decode writes land harmlessly
#            outside every live slot's pages.  Its content is garbage and
#            is only ever read by dead rows, whose outputs are ignored.
#
# On a data-parallel mesh the pool is built as ``shards`` equal extents,
# one per data shard, and EVERY shard carries its own ZERO/DUMP pair at
# the front of its extent (global ids ``g*ext + ZERO_PAGE`` /
# ``g*ext + DUMP_PAGE``): a device-local decode step must never reach a
# reserved page on another device.  ``shards == 1`` is exactly the old
# single-pool layout.
ZERO_PAGE = 0
DUMP_PAGE = 1
RESERVED_PAGES = 2


def shard_of_slot(batch: int, shards: int):
    """Data-axis shard owning each batch slot: slots are pinned in
    contiguous blocks (``slot // (batch/shards)``), matching how a
    ``P(data)`` layout splits the slot dim.  Returns [batch] int32."""
    if shards < 1 or batch % shards:
        raise ValueError(
            f"paged cache: batch {batch} must be a positive multiple of "
            f"shards {shards} (slots are pinned to data shards)")
    return jnp.arange(batch, dtype=jnp.int32) // (batch // shards)


def _shard_dump_ids(batch: int, n_pages: int, shards: int):
    """Per-slot DUMP page id ([batch] int32): the DUMP page of the
    shard-local pool extent the slot is pinned to."""
    if n_pages % shards:
        raise ValueError(
            f"paged cache: pool extent {n_pages} must divide into "
            f"shards {shards} equal per-device extents")
    ext = n_pages // shards
    return shard_of_slot(batch, shards) * ext + DUMP_PAGE


@dataclasses.dataclass(frozen=True)
class PagedKVCache:
    """Block-table paged decode cache of one attention layer.

    The logical cache a slot sees is identical to :class:`KVCache`'s
    ``[cache_len]`` ring/append buffer; physically the rows live in
    fixed-size pages of a shared pool, indirected per batch slot through
    ``block``.  Pages are allocated on first write and freed on retire
    by the serving-side :class:`repro.serve.paging.PageTable`; the model
    layer only reads/writes through the indirection.  ``page_size`` and
    ``cache_len`` are static (pytree aux data), so one lowered decode
    step serves any block-table state.
    """

    kp: jnp.ndarray       # [n_pages, page_size, kv_heads, head_dim] pool
    vp: jnp.ndarray
    block: jnp.ndarray    # [b, n_logical_pages] int32 pool page ids
    length: jnp.ndarray   # [] int32 — high-water mark (as KVCache)
    page_size: int = dataclasses.field(metadata=dict(static=True))
    cache_len: int = dataclasses.field(metadata=dict(static=True))


jax.tree_util.register_dataclass(
    PagedKVCache, data_fields=("kp", "vp", "block", "length"),
    meta_fields=("page_size", "cache_len"))


def n_logical_pages(cache_len: int, page_size: int) -> int:
    """Pages covering a ``cache_len``-slot logical cache (last may be
    partial: the gathered view is sliced back to ``cache_len``)."""
    return -(-cache_len // page_size)


def init_paged_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                        page_size: int, n_pages: int, dtype,
                        shards: int = 1) -> PagedKVCache:
    """Fresh pool of ``n_pages`` (incl. the reserved pages of each of the
    ``shards`` per-device extents) + all-DUMP block tables: every slot is
    dead until the page table assigns pages.  Dead slots dump into the
    DUMP page of *their own shard's* extent so a device-local decode
    never writes across the data axis (``shards == 1``: plain DUMP)."""
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    n_lp = n_logical_pages(cache_len, page_size)
    shape = (n_pages, page_size, kvh, hd)
    dump = _shard_dump_ids(batch, n_pages, shards)
    return PagedKVCache(
        kp=jnp.zeros(shape, dtype), vp=jnp.zeros(shape, dtype),
        block=jnp.broadcast_to(dump[:, None], (batch, n_lp)),
        length=jnp.zeros((), jnp.int32),
        page_size=page_size, cache_len=cache_len)


def paged_kv_view(cache: PagedKVCache):
    """Gather the block-table indirection into the contiguous
    ``[b, cache_len, kv_heads, head_dim]`` layout :class:`KVCache`
    stores directly.  Values land in the exact same slot order, which is
    what makes paged attention bit-identical to contiguous attention."""
    b, n_lp = cache.block.shape
    k = cache.kp[cache.block].reshape(
        (b, n_lp * cache.page_size) + cache.kp.shape[2:])
    v = cache.vp[cache.block].reshape(
        (b, n_lp * cache.page_size) + cache.vp.shape[2:])
    return k[:, :cache.cache_len], v[:, :cache.cache_len]


def attn_decode(params, cfg: ModelConfig, x, cache, pos, kind: str,
                backend: str = "gather"):
    """One-token decode. x: [b, 1, d]; pos: [] or [b] int32 absolute
    position (vector = per-slot positions for continuous batching).

    ``local`` layers use the cache as a ring buffer of ``window_size``
    slots; ``global`` layers append at ``pos``.  ``cache`` is either a
    contiguous :class:`KVCache` or a block-table :class:`PagedKVCache`;
    the attention math runs on the same ``[b, cache_len]`` slot layout
    either way (paged caches gather their pages into it), so the two
    forms decode bit-identically.

    ``backend`` (paged caches only): ``"gather"`` materializes the
    contiguous logical view each step (bit-identical to the contiguous
    cache); ``"pallas_paged"`` runs the Pallas decode kernel
    (:mod:`repro.kernels.paged_attention`) that reads K/V pages through
    the block-table indirection in place — no gathered view is ever
    materialized.  The kernel mirrors the gather math up to
    accumulation order (online softmax over pages), so generations are
    identical while logits agree to interpret-mode tolerance.
    """
    if backend not in ("gather", "pallas_paged"):
        raise ValueError(f"unknown decode backend {backend!r}")
    q, k_new, v_new = _project_qkv(params, cfg, x)
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    posv = pos[:, None] if per_slot else jnp.full((b, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)

    paged = isinstance(cache, PagedKVCache)
    if backend == "pallas_paged" and not paged:
        raise ValueError(
            "decode backend 'pallas_paged' consumes block tables; it "
            "requires a PagedKVCache (serve with paged=PagedCacheConfig)")
    cache_len = cache.cache_len if paged else cache.k.shape[1]
    # cache_len == window_size for local layers (ring buffer), == max_len
    # for global layers (plain append, since pos < max_len).
    slot = pos % cache_len
    if paged:
        # write the new row through the block table (a one-row scatter
        # into the pool page holding ``slot`` — PageTable.prepare_step
        # assigned it; dead slots' tables point at DUMP).
        jdx, off = slot // cache.page_size, slot % cache.page_size
        if per_slot:
            pid = cache.block[jnp.arange(b), jdx]
        else:
            pid = cache.block[:, jdx]
        kp = cache.kp.at[pid, off].set(k_new[:, 0])
        vp = cache.vp.at[pid, off].set(v_new[:, 0])
        new_cache = dataclasses.replace(cache, kp=kp, vp=vp)
        if backend == "pallas_paged":
            # the kernel walks the block table in place; no logical view
            from repro.kernels.paged_attention.ops import paged_attention
            kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            g = cfg.n_heads // kvh
            posb = pos if per_slot else jnp.full((b,), pos, jnp.int32)
            out = paged_attention(
                q[:, 0].reshape(b, kvh, g, hd), kp, vp, new_cache.block,
                posb, cache_len=cache_len,
                window=(cfg.window_size if kind == "local" else None),
                softcap=cfg.attn_softcap)
            out = out.reshape(b, 1, cfg.n_heads * hd)
            new_len = jnp.minimum(jnp.max(pos) + 1, cache_len)
            new_cache = dataclasses.replace(
                new_cache, length=new_len.astype(jnp.int32))
            return out @ params["wo"], new_cache
        k, v = paged_kv_view(new_cache)
    elif per_slot:
        rows = jnp.arange(b)
        k = cache.k.at[rows, slot].set(k_new[:, 0])
        v = cache.v.at[rows, slot].set(v_new[:, 0])
    else:
        k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))

    kv_pos = _cache_positions(cache_len, pos)   # [L] or [b, L]
    valid = kv_pos >= 0
    if kind == "local" and cfg.window_size is not None:
        valid &= kv_pos > (pos[:, None] if per_slot else pos) - cfg.window_size
    if valid.ndim == 1:
        valid = valid[None]                      # [1, L] broadcasts over b
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    scale = hd ** -0.5
    g = cfg.n_heads // kvh
    # Cache sharding choice (mirrors serve.engine.cache_specs): enough
    # KV heads to fill the model axis -> shard heads; otherwise shard
    # the cache *length* (flash-decode-style distributed attention with
    # a GSPMD all-reduce over the softmax stats).  The grouped einsum
    # keeps the cache unexpanded: decode is cache-bandwidth-bound and
    # repeating K/V g-fold would inflate the memory roofline term.
    env = current_env()
    msize = env.size("M") if env else None
    if env is not None and env.seq is not None:
        kv_tags = ("B", "S", None, None)       # long-context: shard length
    elif msize and kvh % msize == 0:
        kv_tags = ("B", None, "M", None)       # enough heads: shard heads
    else:
        kv_tags = ("B", "M", None, None)       # few heads: shard length on M
    k = constrain(k, *kv_tags)
    v = constrain(v, *kv_tags)
    qh = q.reshape(b, 1, kvh, g, hd)
    # RoPE for cached keys was applied at insert time; kv cache stores
    # post-rope keys, so attend directly.
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh, k).astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v).reshape(b, 1, -1)
    new_len = jnp.minimum(jnp.max(pos) + 1, cache_len).astype(jnp.int32)
    if paged:
        new_cache = dataclasses.replace(new_cache, length=new_len)
    else:
        new_cache = KVCache(k, v, new_len)
    return out @ params["wo"], new_cache


def _cache_positions(cache_len: int, pos):
    """Absolute position stored in each ring slot (-1 if empty).

    Slot s holds the newest absolute position p <= pos with p % L == s.
    ``pos`` may be scalar (-> [L]) or [b] (-> [b, L]).
    """
    slots = jnp.arange(cache_len)
    cur_slot = pos % cache_len
    newest = pos[..., None] - ((cur_slot[..., None] - slots) % cache_len)
    return jnp.where(newest >= 0, newest, -1)
