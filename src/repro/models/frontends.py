"""Modality frontend STUBS for the [vlm]/[audio] architectures.

Per the assignment, these entries specify the transformer BACKBONE
only; the modality frontend provides *precomputed* patch/frame
embeddings through ``input_specs()``.  The stubs below define the
embedding geometry (so shapes/shardings are exact) and a deterministic
synthetic generator for smoke tests / examples.

  * ``vision`` — InternViT-300M patch embeddings projected to the
    backbone width: 1025 tokens (32x32 patches + CLS) per image tile.
  * ``audio``  — EnCodec frame embeddings (4 codebooks summed) at
    50 Hz: the token stream itself for MusicGen's decoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["frontend_tokens", "synth_embeddings"]

VISION_TOKENS = 1025   # 448x448 image, 14px patches, pixel-shuffle /2 + CLS
AUDIO_FRAME_HZ = 50


def frontend_tokens(cfg: ModelConfig) -> int:
    """Prompt positions occupied by frontend embeddings."""
    if cfg.frontend == "vision":
        return VISION_TOKENS
    if cfg.frontend == "audio":
        return 0  # MusicGen conditions via a (stubbed) prefix, not extra tokens
    return 0


def synth_embeddings(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic frontend output: [batch, seq, d_model]."""
    key = jax.random.fold_in(jax.random.key(seed), batch * 131 + seq)
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) * 0.02
