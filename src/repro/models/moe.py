"""Mixture-of-Experts FFN: top-k routing, group-wise capacity dispatch.

Dispatch is **group-wise per batch element**: each sequence ranks its
own tokens into per-expert capacity slices and scatters into its own
buffer row.  Every scatter/gather is then local to the (data-sharded)
batch dimension — GSPMD never has to all-reduce a dispatch buffer (the
naive global scatter materialized full multi-GiB expert buffers per
device on the 100B MoE train cells).  Expert compute is a batched
einsum ``becd,edf->becf`` whose b (data) and e (model) dims are plain
batch dims, so the sharding survives the backward pass cleanly.

Capacity: per sequence, ``max(1, cf * k * seq / e)``; overflow tokens
within a sequence drop (standard dropping MoE; decode's seq=1 never
drops since each virtual expert receives at most one routing slot).

Virtual expert split (``cfg.moe_virtual_split = s``): each expert is
stored/computed as ``s`` experts of width ``d_ff/s`` — exact for gated
MLPs (f-slices independent through the activation, wo row-blocks sum)
and chosen so the expert count divides the production model axis
(mixtral: 8 x 2 -> 16).  A Switch-style load-balance aux loss is
computed on the real experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.axisenv import constrain, current_env
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = cfg.moe_virtual_split
    if f % s:
        raise ValueError("d_ff must divide moe_virtual_split")
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi": dense_init(ks[1], (e * s, d, f // s), dtype),
        "wo": dense_init(ks[2], (e * s, f // s, d), dtype),
    }
    if cfg.mlp_gated:
        p["wg"] = dense_init(ks[3], (e * s, d, f // s), dtype)
    return p


def moe_apply(params, cfg: ModelConfig, x, capacity: int | None = None,
              token_mask=None):
    """x: [b, seq, d] -> (y: [b, seq, d], aux_loss: scalar f32).

    ``capacity`` overrides the per-(virtual-)expert slot count.  Pass
    ``seq`` for *dropless* dispatch (each expert can absorb every token
    of the sequence): serving prefill must match the decode path, which
    never drops — capacity-dropping is a train-time regularizer, not an
    inference semantic.

    ``token_mask`` ([b, seq] bool): False (padded) tokens are excluded
    from dispatch entirely — they claim no expert rank and scatter to
    the discard slot — so per-expert occupancy is computed from *real*
    token counts and a right-padded sequence routes real tokens exactly
    as its unpadded twin would (padding only ever appends to the
    exclusive-cumsum rank order, it never displaces a real token).
    """
    b, seq, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    vs = cfg.moe_virtual_split

    logits = x.astype(jnp.float32) @ params["router"]            # [b,seq,e]
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # [b,seq,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    aux = _aux_loss(probs.reshape(-1, e), expert_idx.reshape(-1, k), e)

    # --- virtual expert split (layout-only; see module docstring) --------
    if vs > 1:
        e = e * vs
        k = k * vs
        expert_idx = (expert_idx[..., None] * vs
                      + jnp.arange(vs)[None, None, None, :]
                      ).reshape(b, seq, k)
        gate_vals = jnp.repeat(gate_vals, vs, axis=-1)

    if capacity is None:
        capacity = max(1, int(cfg.moe_capacity_factor * k * seq / e)) \
            if seq > 1 else k
    nk = seq * k

    # --- per-sequence rank within expert ---------------------------------
    flat_idx = expert_idx.reshape(b, nk)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)        # [b,nk,e]
    if token_mask is not None:
        # [b, seq] -> [b, nk]: token t owns flat entries t*k .. t*k+k-1
        mflat = jnp.repeat(token_mask, k, axis=1)
        onehot = onehot * mflat[..., None].astype(jnp.int32)
    ranks = jnp.cumsum(onehot, axis=1) - onehot                  # exclusive
    pos = jnp.sum(ranks * onehot, axis=-1)                       # [b,nk]
    keep = pos < capacity
    if token_mask is not None:
        keep = keep & mflat
    slot = jnp.where(keep, flat_idx * capacity + pos, e * capacity)

    # --- dispatch: local scatter per batch element --------------------------
    src = jnp.broadcast_to(x[:, :, None, :], (b, seq, k, d)
                           ).reshape(b, nk, d)

    def scatter_one(src_b, slot_b):
        buf = jnp.zeros((e * capacity + 1, d), x.dtype)
        return buf.at[slot_b].add(src_b)

    buf = jax.vmap(scatter_one)(src, slot)[:, :-1, :]            # [b,e*c,d]
    xin = constrain(buf.reshape(b, e, capacity, d),
                    "B", _etag(e), None, None)

    # --- expert computation (b, e are batch dims: stays local) -------------
    h = constrain(jnp.einsum("becd,edf->becf", xin, params["wi"]),
                  "B", _etag(e), None, None)
    if "wg" in params:
        act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.mlp_activation]
        h = act(constrain(jnp.einsum("becd,edf->becf", xin, params["wg"]),
                          "B", _etag(e), None, None)) * h
    else:
        h = jax.nn.silu(h)
    yout = jnp.einsum("becf,efd->becd", h, params["wo"])
    yout = constrain(yout, "B", _etag(e), None, None)
    yout = yout.reshape(b, e * capacity, d)

    # --- combine: local gather per batch element, gate-weighted -------------
    zero_row = jnp.zeros((b, 1, d), yout.dtype)
    yext = jnp.concatenate([yout, zero_row], axis=1)
    gathered = jnp.take_along_axis(
        yext, slot[..., None].astype(jnp.int32), axis=1)         # [b,nk,d]
    gathered = constrain(gathered, "B", None, None)
    w = (gate_vals.reshape(b, nk) * keep).astype(gathered.dtype)
    y = jnp.sum(gathered.reshape(b, seq, k, d)
                * w.reshape(b, seq, k)[..., None], axis=2)
    return y, aux


def _etag(e):
    env = current_env()
    msize = env.size("M") if env else None
    return "M" if (msize and e % msize == 0) else None


def _aux_loss(probs, expert_idx, e):
    """Switch-style load-balance loss (on the REAL experts)."""
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0
    )
    mean_probs = jnp.mean(probs, axis=0)
    return (e * jnp.sum(density * mean_probs)).astype(jnp.float32)
