#!/usr/bin/env python
"""Documentation gate: markdown link-check + package-docstring lint.

Two checks, both pure stdlib (no jax — this must run in a bare CI
container in seconds):

1. **Link check.**  Every markdown link in ``README.md``,
   ``ROADMAP.md``, ``CHANGES.md``, and ``docs/*.md`` must resolve:
   relative targets must exist on disk (relative to the file holding
   the link), and ``#anchor`` fragments must match a heading in the
   target file (GitHub's slug rules: lowercase, punctuation stripped,
   spaces to hyphens).  External ``http(s)://`` links are not fetched —
   this gate is about the repo's own files staying in sync with the
   prose that cites them.

2. **Design-note docstring lint.**  Every ``src/repro/*`` package (and
   ``repro`` itself) must open with a non-trivial module docstring —
   the package docstrings ARE the design record (see
   ``docs/ARCHITECTURE.md``), so an empty or one-liner docstring on a
   package is a regression.  Parsed with ``ast``; nothing is imported.

Exit status is the number of problems (0 = green).  Run from anywhere:
``python tools/check_docs.py``.
"""
from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_FILES = ["README.md", "ROADMAP.md", "CHANGES.md"]
DOCS_DIR = "docs"
PKG_ROOT = os.path.join("src", "repro")
MIN_DOCSTRING_CHARS = 200   # a design note, not a placeholder

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must resolve too.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's heading-to-anchor slug (the subset we rely on)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)             # inline markup
    s = re.sub(r"[^\w\- ]", "", s)          # punctuation
    return s.replace(" ", "-")


def md_anchors(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = _CODE_FENCE.sub("", f.read())
    return {github_slug(h) for h in _HEADING.findall(text)}


def iter_md_files():
    for name in MD_FILES:
        p = os.path.join(REPO, name)
        if os.path.exists(p):
            yield p
    docs = os.path.join(REPO, DOCS_DIR)
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                yield os.path.join(docs, name)


def check_links() -> list:
    problems = []
    for md in iter_md_files():
        rel_md = os.path.relpath(md, REPO)
        with open(md, encoding="utf-8") as f:
            text = _CODE_FENCE.sub("", f.read())
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            if path_part:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(md), path_part))
                if not os.path.exists(dest):
                    problems.append(
                        f"{rel_md}: dead link -> {target} "
                        f"(no such file {os.path.relpath(dest, REPO)})")
                    continue
            else:
                dest = md
            if anchor:
                if not dest.endswith(".md"):
                    continue            # anchors into code: not checked
                if anchor not in md_anchors(dest):
                    problems.append(
                        f"{rel_md}: dead anchor -> {target} "
                        f"(no heading slug '{anchor}' in "
                        f"{os.path.relpath(dest, REPO)})")
    return problems


def check_docstrings() -> list:
    problems = []
    root = os.path.join(REPO, PKG_ROOT)
    # `repro` itself is a namespace package (no __init__.py); the lint
    # covers every src/repro/* subpackage, and a subpackage missing its
    # __init__.py entirely is itself a finding.
    inits = []
    for d in sorted(os.listdir(root)):
        if not os.path.isdir(os.path.join(root, d)) or d.startswith("__"):
            continue
        init = os.path.join(root, d, "__init__.py")
        if not os.path.exists(init):
            problems.append(
                f"{PKG_ROOT}/{d}: no __init__.py — every repro "
                f"subpackage carries its design note there")
            continue
        inits.append(init)
    for init in inits:
        rel = os.path.relpath(init, REPO)
        try:
            tree = ast.parse(open(init, encoding="utf-8").read())
        except SyntaxError as e:
            problems.append(f"{rel}: unparseable ({e})")
            continue
        doc = ast.get_docstring(tree) or ""
        if len(doc.strip()) < MIN_DOCSTRING_CHARS:
            problems.append(
                f"{rel}: package docstring is "
                f"{len(doc.strip())} chars (< {MIN_DOCSTRING_CHARS}) — "
                f"packages carry their design notes in the docstring")
    return problems


def main() -> int:
    problems = check_links() + check_docstrings()
    for p in problems:
        print(f"FAIL {p}")
    n_md = len(list(iter_md_files()))
    print(f"checked {n_md} markdown files + src/repro package "
          f"docstrings: {len(problems)} problem(s)")
    return min(len(problems), 99)


if __name__ == "__main__":
    sys.exit(main())
