"""Trace-driven Fig. 10 analogue: RTC variant savings from live serves.

The original ``fig10_savings`` grid evaluates the closed-form RTC
variants on *analytic* CNN workload profiles.  This benchmark closes
the serving loop instead: a paged :class:`repro.serve.ServeEngine`
serves a fixed mixed-length request trace, its per-step page-access
trace (:mod:`repro.core.trace`) is mapped onto a pool-sized DRAM module
under every placement policy (:mod:`repro.core.placement`), and the
event-level simulator (:func:`repro.core.refresh_sim.simulate_trace`)
replays the measured touched-rows stream through each refresh variant —
the paper's Fig. 10 axes (variant x configuration), but with *measured*
accesses on the variant axis and the DRMap/PENDRAM-style mapping
policies as the configuration axis.

Page accesses depend on context lengths and scheduling, never on
sampled token values, so with the fixed seeds/prompts below every
number here is deterministic and the derived counts are pinned by
``tests/test_trace_sim.py``.  ``rate_matching`` ties the rows back to
the closed-form model: ``implicit_fraction`` is the share of refreshes
the access stream itself absorbed.

    python benchmarks/fig10_trace.py
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import jax
import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.configs import get_config
from repro.core.placement import (PLACEMENT_POLICIES, build_placement,
                                  fitting_spec)
from repro.core.refresh_sim import simulate_trace
from repro.core.rtc import Variant
from repro.core.trace import PageAccessTrace, window_masks
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, ServeEngine, ServeTelemetry,
                         TrafficModel)

# one attention-only arch, one with recurrent state pages: the state
# streams are where slot co-location differs from row-major packing
ARCHS = ("qwen1.5-0.5b", "recurrentgemma-2b")
VARIANTS = (Variant.BASELINE, Variant.MID_RTC, Variant.FULL_RTC,
            Variant.SMART_REFRESH)
PROMPT_LENS = (4, 9, 6, 12)
NEW_TOKENS = 12
PAGE_SIZE = 8
_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}


def serve_trace(arch: str):
    """One deterministic serve through a tightly budgeted paged engine
    (the small resident budget forces mid-serve offload/restore, so the
    trace carries page-out/in rows, not just steady-state sweeps)."""
    smoke = get_config(arch, smoke=True)
    model = TransformerLM(smoke)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=32, max_batch=2,
                         paged=PagedCacheConfig(page_size=PAGE_SIZE,
                                                resident_pages=6))
    trace = PageAccessTrace(engine._table.stream_names())
    tele = ServeTelemetry(TrafficModel.from_config(smoke, max_len=32,
                                                   page_size=PAGE_SIZE),
                          trace=trace)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, smoke.vocab_size, (n,)).astype(np.int32)
               for n in PROMPT_LENS]
    engine.serve(prompts, max_new_tokens=NEW_TOKENS, seed=7, telemetry=tele)
    pbytes = smoke.param_counts()["total"] * _ITEMSIZE[smoke.dtype]
    return trace, engine._table.stream_geometries(), pbytes


def run():
    from repro.core.rate_matching import implicit_fraction

    rows = []
    for arch in ARCHS:
        trace, geoms, pbytes = serve_trace(arch)
        spec = fitting_spec(geoms, param_bytes=pbytes)
        for policy in PLACEMENT_POLICIES:
            pl = build_placement(policy, spec, geoms, param_bytes=pbytes)
            masks = window_masks(trace, pl)
            mean_touched = float(masks.sum(axis=1).mean())
            row = {
                "arch": arch,
                "policy": policy,
                "n_rows": spec.n_rows,
                "alloc_rows": pl.alloc_rows,
                "rows_used": pl.rows_used(),
                "n_windows": int(masks.shape[0]),
                "mean_rows_touched": mean_touched,
                # closed-form rate-matching tie-in: the measured mean
                # access rate vs the allocation's refresh obligations
                "implicit_fraction": implicit_fraction(
                    mean_touched, pl.alloc_rows),
            }
            for var in VARIANTS:
                res = simulate_trace(
                    spec, var, masks=masks, alloc_lo=pl.alloc_lo,
                    alloc_rows=pl.alloc_rows,
                    bank_rounded=(var is Variant.MID_RTC))
                assert res.violations == 0, (arch, policy, var, res)
                row[var.value] = {
                    "implicit": res.implicit_refreshes,
                    "explicit": res.explicit_refreshes,
                    "refresh_savings": res.refresh_savings,
                }
            rows.append(row)
    return rows


def main():
    rows, us = timed(run, repeat=1)
    per = us / len(rows)
    for r in rows:
        emit(f"fig10_trace_{r['arch']}_{r['policy']}", per,
             f"full={r['full-rtc']['refresh_savings']:.3f} "
             f"mid={r['mid-rtc']['refresh_savings']:.3f} "
             f"smart={r['smart-refresh']['refresh_savings']:.3f} "
             f"touched/win={r['mean_rows_touched']:.0f}/{r['alloc_rows']}")
    save_json("fig10_trace", rows)


if __name__ == "__main__":
    main()
