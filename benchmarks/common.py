"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (harness
contract) — ``us_per_call`` measures the evaluation itself on CPU,
``derived`` carries the paper-relevant quantity (a savings fraction,
an energy share, ...).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timed(fn: Callable, repeat: int = 3):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
