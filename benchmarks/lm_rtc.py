"""Beyond-paper: RTC energy savings for the 10 assigned LM architectures.

Applies the paper's mechanism to modern LM steps (edge-serving regime:
weights resident in LPDDR-class memory).  Decode steps re-stream the
*active* weights every few ms — far above the refresh rate — so RTT is
ideal for dense archs, while MoE archs leave inactive experts untouched
(the Algorithm-1 partial-coverage regime) and small archs on big
modules lean on PAAR.  Step periods come from the dry-run roofline
bound when cached, else a 50 tok/s serving assumption.
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import glob
import json
import os

from benchmarks.common import emit, save_json, timed
from repro.configs import ARCH_IDS, get_config
from repro.core.allocator import allocate_workload
from repro.core.dram import module
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.trace import lm_workload

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _step_time(arch: str, default: float = 0.02) -> float:
    path = os.path.join(DRYRUN_DIR, f"{arch}__decode_32k__pod__baseline.json")
    if os.path.exists(path):
        rec = json.load(open(path))
        if not rec.get("skipped") and rec.get("step_time_bound_s"):
            return max(rec["step_time_bound_s"], 1e-4)
    return default


def run():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        w = lm_workload(cfg, "decode", _step_time(arch),
                        global_batch=8, seq_len=8192)
        # module sized to the smallest of (2/4/8/16/32/64) GB that fits
        for gb in (2, 4, 8, 16, 32, 64, 128, 256, 512):
            spec = module(gb)
            if w.footprint_bytes <= spec.capacity_bytes * 0.95:
                break
        alloc = allocate_workload(spec, {"data": w.footprint_bytes})
        rep = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
        rtt, paar = rtt_paar_split(spec, w, alloc)
        rows.append({
            "arch": arch, "family": cfg.family, "dram_gb": gb,
            "footprint_gb": w.footprint_bytes / 2**30,
            "rtt": rtt, "paar": paar,
            "dram_savings": rep.dram_savings,
            "refresh_savings": rep.refresh_savings,
        })
    return rows


def main():
    rows, us = timed(run, repeat=1)
    for r in rows:
        emit(f"lm_rtc_{r['arch']}", us / len(rows),
             f"refresh_savings={r['refresh_savings']:.3f} "
             f"dram_savings={r['dram_savings']:.3f} ({r['dram_gb']}GB)")
    save_json("lm_rtc", rows)


if __name__ == "__main__":
    main()
