"""Beyond-paper: RTC energy savings for the 10 assigned LM architectures.

Applies the paper's mechanism to modern LM serving (edge regime:
weights resident in LPDDR-class memory).  The DRAM profile is no longer
hand-built: the continuous-batching :class:`repro.serve.ServeEngine`
serves a mixed-prompt-length request trace (smoke-scale model — the
*scheduling* is what is measured) and its telemetry converts the trace
to bytes with the full-size config's constants, emitting the
:class:`~repro.core.workload.WorkloadProfile` that ``rtc.evaluate``
consumes.  Decode steps re-stream the *active* weights every few ms —
far above the refresh rate — so RTT is ideal for dense archs, while MoE
archs leave inactive experts untouched (the Algorithm-1
partial-coverage regime) and small archs on big modules lean on PAAR.
Step periods come from the dry-run roofline bound when cached, else a
50 tok/s serving assumption.

Since PR 9 the engine is paged and also emits its per-step page-access
trace: each row carries ``trace_refresh_savings`` — FULL_RTC savings
replayed from the *measured* access stream under every placement
policy (:mod:`repro.core.placement`), next to the analytic profile's
numbers (whose accounting is pinned to the contiguous mode and is
unchanged).
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import json
import os

import jax
import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.configs import ARCH_IDS, get_config
from repro.core.allocator import allocate_workload
from repro.core.dram import GiB, smallest_fitting_module
from repro.core.placement import (PLACEMENT_POLICIES, build_placement,
                                  fitting_spec)
from repro.core.refresh_sim import simulate_trace
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.trace import PageAccessTrace, window_masks
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, ServeEngine, ServeTelemetry,
                         TrafficModel)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SERVE_CTX = 8192        # deployment context the byte constants assume
ENGINE_LEN = 32         # smoke engine cache length (CPU-sized)
PROMPT_LENS = (4, 9, 6, 12)
NEW_TOKENS = 8


def _step_time(arch: str, default: float = 0.02) -> float:
    path = os.path.join(DRYRUN_DIR, f"{arch}__decode_32k__pod__baseline.json")
    if os.path.exists(path):
        rec = json.load(open(path))
        if not rec.get("skipped") and rec.get("step_time_bound_s"):
            return max(rec["step_time_bound_s"], 1e-4)
    return default


_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}


def _serve_telemetry(arch: str):
    """Serve a mixed-length request trace through the batched engine.

    The engine runs the smoke config (CPU-sized compute); the telemetry
    carries the FULL config's byte constants, so the emitted profile
    pairs a *measured* scheduling trace with production byte magnitudes.

    The engine is paged (page_size=8, ample budget) so it also emits
    the per-step page-access trace, but ``decode_mode`` stays pinned to
    ``"contiguous"``: the analytic profile — and every savings number
    derived from it — is byte-identical to the old contiguous engine's
    (ample-budget paged serving schedules and generates identically).
    Returns ``(telemetry, trace_refresh_savings)`` where the latter is
    the measured-trace FULL_RTC savings per placement policy on a
    module sized to the engine's own pools.
    """
    smoke = get_config(arch, smoke=True)
    model = TransformerLM(smoke)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=ENGINE_LEN, max_batch=2,
                         paged=PagedCacheConfig(page_size=8))
    trace = PageAccessTrace(engine._table.stream_names())
    # ctx_scale maps the smoke engine's measured per-slot occupancy onto
    # the deployment context, so KV traffic carries SERVE_CTX magnitudes
    # (not the 32-token smoke contexts) while keeping the trace's shape.
    tele = ServeTelemetry(TrafficModel.from_config(get_config(arch),
                                                   max_len=SERVE_CTX),
                          ctx_scale=SERVE_CTX / ENGINE_LEN,
                          decode_mode="contiguous", trace=trace)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, smoke.vocab_size, (n,)).astype(np.int32)
               for n in PROMPT_LENS]
    engine.serve(prompts, max_new_tokens=NEW_TOKENS, telemetry=tele)

    geoms = engine._table.stream_geometries()
    pbytes = smoke.param_counts()["total"] * _ITEMSIZE[smoke.dtype]
    spec = fitting_spec(geoms, param_bytes=pbytes)
    trace_savings = {}
    for pol in PLACEMENT_POLICIES:
        pl = build_placement(pol, spec, geoms, param_bytes=pbytes)
        res = simulate_trace(spec, Variant.FULL_RTC,
                             masks=window_masks(trace, pl),
                             alloc_lo=pl.alloc_lo, alloc_rows=pl.alloc_rows)
        assert res.violations == 0, (arch, pol, res)
        trace_savings[pol] = res.refresh_savings
    return tele, trace_savings


def run():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        tele, trace_savings = _serve_telemetry(arch)
        w = tele.workload_profile(name=f"{cfg.name}/serve",
                                  step_period_s=_step_time(arch))
        spec = smallest_fitting_module(w.footprint_bytes)
        gb = spec.capacity_bytes // GiB
        alloc = allocate_workload(spec, {"data": w.footprint_bytes})
        rep = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
        rtt, paar = rtt_paar_split(spec, w, alloc)
        rows.append({
            "arch": arch, "family": cfg.family, "dram_gb": gb,
            "footprint_gb": w.footprint_bytes / 2**30,
            "read_gb_per_step": w.read_bytes_per_iter / 2**30,
            "decode_steps": tele.decode_steps,
            "tokens_generated": tele.tokens_generated,
            "rtt": rtt, "paar": paar,
            "dram_savings": rep.dram_savings,
            "refresh_savings": rep.refresh_savings,
            "trace_refresh_savings": trace_savings,
        })
    return rows


def main():
    rows, us = timed(run, repeat=1)
    for r in rows:
        ts = r["trace_refresh_savings"]
        emit(f"lm_rtc_{r['arch']}", us / len(rows),
             f"refresh_savings={r['refresh_savings']:.3f} "
             f"dram_savings={r['dram_savings']:.3f} "
             f"trace[rm/bi/sc]="
             + "/".join(f"{ts[p]:.3f}" for p in PLACEMENT_POLICIES)
             + f" ({r['dram_gb']}GB, {r['decode_steps']} engine steps)")
    save_json("lm_rtc", rows)


if __name__ == "__main__":
    main()
