"""Paper Fig. 12: refresh share of DRAM energy vs chip density.

A chip running at peak bandwidth (the paper's setup, [24,35]): refresh
grows toward ~46-47% of DRAM energy at 64 Gb for conventional DRAM,
while RTC-enabled DRAM nearly eliminates it for CNN-style workloads
(PAAR bounds refresh to the footprint; RTT coalesces within it).
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import dataclasses

from benchmarks.common import emit, save_json, timed
from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import FIG12_DENSITIES_GBIT, chip
from repro.core.energy import dram_power
from repro.core.rtc import Variant, evaluate
from repro.core.workload import WorkloadProfile, from_cnn

PEAK_BW = 51.2e9  # B/s — wide-interface 3D stack (Section V topology)


def run():
    rows = []
    for gbit in FIG12_DENSITIES_GBIT:
        spec = chip(gbit, peak_bw_bytes=PEAK_BW)
        # peak-bandwidth streaming workload over the CNN working set
        base_cnn = from_cnn(CNN_ZOO["alexnet"], fps=60)
        w = dataclasses.replace(
            base_cnn,
            name=f"peakbw@{gbit}Gb",
            read_bytes_per_iter=PEAK_BW * base_cnn.iter_period_s * 0.9,
            write_bytes_per_iter=PEAK_BW * base_cnn.iter_period_s * 0.1,
        )
        baseline = dram_power(spec, w)
        alloc = allocate_workload(
            spec, {"data": min(w.footprint_bytes, spec.capacity_bytes)})
        rtc = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
        rows.append({
            "density_gbit": gbit,
            "baseline_refresh_share": baseline.refresh_fraction,
            "rtc_refresh_share": rtc.policy.refresh / rtc.policy.total,
        })
    return rows


def main():
    rows, us = timed(run, repeat=1)
    for r in rows:
        emit(f"fig12_{r['density_gbit']}Gb", us / len(rows),
             f"baseline={r['baseline_refresh_share']:.3f} "
             f"rtc={r['rtc_refresh_share']:.3f}")
    last = rows[-1]
    emit("fig12_64Gb_baseline_share", us / len(rows),
         f"{last['baseline_refresh_share']:.3f} (paper ~0.46)")
    save_json("fig12_scaling", rows)


if __name__ == "__main__":
    main()
