"""Paper Fig. 11: RTC vs SmartRefresh [17] on an 8 GB module.

Setup per Section VI-B: row size 2048 B (4,194,304 rows -> one 3-bit
counter each for SmartRefresh), multiple CNN instances co-run at 60 fps
to utilize bandwidth.  Validates: RTC saves ~28% (access-heavy mixes)
to ~96% (LeNet-only) more DRAM energy than SmartRefresh.
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

from benchmarks.common import emit, save_json, timed
from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import MODULE_8GB
from repro.core.rtc import Variant, evaluate
from repro.core.workload import from_cnn, merge

MIXES = [
    ("LN", [("lenet", 1)]),
    ("GN", [("googlenet", 1)]),
    ("AN", [("alexnet", 1)]),
    ("AN+GN", [("alexnet", 1), ("googlenet", 1)]),
    ("2AN+2GN+LN", [("alexnet", 2), ("googlenet", 2), ("lenet", 1)]),
]


def run():
    spec = MODULE_8GB
    rows = []
    for label, parts in MIXES:
        ws = []
        for cnn, n in parts:
            w = from_cnn(CNN_ZOO[cnn], fps=60)
            ws.extend([w] * n)
        wl = merge(label, *ws)
        alloc = allocate_workload(spec, {"data": wl.footprint_bytes})
        rtc = evaluate(spec, wl, Variant.FULL_RTC, alloc)
        smart = evaluate(spec, wl, Variant.SMART_REFRESH, alloc)
        rows.append({
            "mix": label,
            "rtc_savings": rtc.dram_savings,
            "smart_savings": smart.dram_savings,
            "rtc_over_smart": rtc.dram_savings - smart.dram_savings,
        })
    return rows


def main():
    rows, us = timed(run, repeat=1)
    for r in rows:
        emit(f"fig11_{r['mix']}", us / len(rows),
             f"rtc={r['rtc_savings']:.3f} smart={r['smart_savings']:.3f} "
             f"delta={r['rtc_over_smart']:.3f}")
    deltas = [r["rtc_over_smart"] for r in rows]
    emit("fig11_delta_range", us / len(rows),
         f"{min(deltas):.2f}..{max(deltas):.2f} (paper ~0.28..0.96)")
    save_json("fig11_smartrefresh", rows)


if __name__ == "__main__":
    main()
