"""sys.path setup for directly-invoked benchmark scripts.

``python benchmarks/<script>.py`` puts only ``benchmarks/`` on
``sys.path``; importing this module (guarded by ``if __package__ in
(None, "")`` in each script) prepends the repo root and ``src/`` so
``benchmarks.*`` and ``repro.*`` resolve without ``-m`` + PYTHONPATH.
"""
import os
import sys

_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_root, "src"), _root):
    if _p not in sys.path:
        sys.path.insert(0, _p)
