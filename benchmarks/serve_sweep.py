"""Serve decode-timing sweep: paged gather vs Pallas block-table kernel.

The perf-trajectory harness CI has been missing: serves an identical
mixed-length workload through two paged engines — ``decode_backend=
"gather"`` (materializes the contiguous logical view every step) and
``"pallas_paged"`` (the :mod:`repro.kernels.paged_attention` kernel
reading pages in place, interpret mode on CPU) — and records per-arch
decode steps/sec plus the telemetry byte split (row-exact KV sweep vs
phantom gather traffic vs per-page kernel reads).  Results land in
``BENCH_serve.json`` (schema below), which the CI ``kernels`` job
uploads as a workflow artifact so the numbers accumulate a trajectory
across PRs instead of staying empty.

Absolute CPU timings are hardware noise; the schema keeps them anyway
(trajectory > precision) next to the byte accounting, which is exact.
Generations are asserted identical across backends on every swept arch
— the bench doubles as a parity smoke.

Each row also carries the *static* per-step byte count: the jaxpr-level
audit (:mod:`repro.analysis`) of the very decode executable the sweep
timed, at full occupancy, next to the telemetry split — so the
trajectory captures auditor/telemetry agreement (``static_match``)
per arch and backend, not just throughput.

v4 lands ROADMAP item 3's device-local decode in the trajectory.  The
script forces a 2-device host CPU topology before jax initializes, so
next to the solo gather/pallas rows it times a real ``shard_map``
engine (``shards=2``: slots and pool extents pinned per device, the
kernel reading only its local pool) and asserts its generations match
the solo rows bit-for-bit.  The partitioning dry-run
(``python -m repro.analysis --mesh 8 --mesh 64 --mesh 512
--partition-only``, one subprocess so the forced 512-device topology
never touches the timed engines) becomes a per-row ``mesh_matrix``:
for each audited mesh size, the decode step's per-device HBM bill
under the weak-scaling audit geometry and its total cross-device wire
bytes per device per step — both exact.  The per-device bill must be
identical across the matrix (weak scaling), and with the device-local
layout no pool byte moves cross-device at any size; the analysis CI
gate owns those assertions, the bench keeps the trajectory.

v5 closes the trace loop (ROADMAP item 4): every timed engine also
records its per-step page-access trace (``telemetry.trace``), and each
row carries ``trace_rtc`` — the measured-trace RTC refresh savings
under every :data:`repro.core.placement.PLACEMENT_POLICIES` mapping of
the engine's pools onto a pool-sized DRAM module — plus
``trace_vs_analytic``, the cross-check that the affine cursor fed the
trace's mean per-window row count reproduces the trace-driven savings
(the two access models must agree on a near-stationary decode stream;
drift fails the run).  Traces are also asserted identical across
backends per arch: page residency is scheduling, not kernel choice.

v6 adds the prefix-sharing row (ROADMAP item 2): per arch, a fourth
engine (gather, solo, ``PagedCacheConfig(sharing=...)``) serves a
same-prefix workload — one exact duplicate (the whole-prompt memo's
full prefill skip), one strict-prefix prompt, one unique — next to an
unshared *twin* engine on the identical workload, asserted
bit-identical.  The three baseline variants keep sharing OFF (their
columns stay comparable across the v5→v6 bump; ``"prefix": None``
marks them).  The sharing row carries a ``prefix`` dict: hit vs
written admission bytes (their sum equals the twin's unshared total —
the telemetry exact-sum invariant), COW fork copy bytes, attached page
count, full skips, the ``savings_frac`` headline, and the measured
per-step trace row-set totals for both engines (the shared total can
only shrink).  Window-limited archs (gemma2's local rings,
recurrentgemma's state pages) legitimately share less or nothing —
the CI gate requires at least one row with real hits and a full skip,
not every row.

Schema (``BENCH_serve.json``)::

    {"schema": "serve-decode-v6",
     "rows": [{"arch", "batch", "backend", "shards", "decode_steps",
               "steps_per_sec", "tok_per_sec",
               "kv_read_bytes_per_step", "gather_bytes_per_step",
               "static_bytes_per_step", "static_classes",
               "static_match", "page_size",
               "trace_rtc": {"<policy>": {"refresh_savings",
                                          "alloc_rows", "rows_used",
                                          "mean_rows_touched"}, ...},
               "trace_vs_analytic": {"trace_savings", "affine_savings",
                                     "delta", "match"},
               "mesh_matrix": {"<N>": {"static_per_device_bytes",
                                       "collective_bytes"}, ...},
               "prefix": None | {"hit_bytes", "admit_write_bytes",
                                 "cow_bytes", "hit_pages", "full_skips",
                                 "savings_frac", "trace_step_pages",
                                 "twin_step_pages"}}, ...]}

    python benchmarks/serve_sweep.py [--archs all] [--out BENCH_serve.json]
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import os

# Two host CPU devices for the shard_map row — set before jax imports.
# The solo rows are unaffected (their engines jit on device 0).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import subprocess
import sys
import tempfile

import jax
import numpy as np

from benchmarks.common import emit
from repro.analysis import decode_traffic_report, unit_from_engine
from repro.configs import ARCH_IDS, get_config
from repro.core.placement import (PLACEMENT_POLICIES, build_placement,
                                  fitting_spec)
from repro.core.refresh_sim import simulate, simulate_trace
from repro.core.rtc import Variant
from repro.core.trace import PageAccessTrace, window_masks
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, PrefixSharingConfig, ServeEngine,
                         ServeTelemetry, TrafficModel)

_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}

# Default sweep: one arch per cache family (dense GQA append, softcap +
# local/global ring mix, recurrent state pages) keeps the CI step small;
# --archs all covers the zoo.
DEFAULT_ARCHS = ("qwen1.5-0.5b", "gemma2-9b", "recurrentgemma-2b")
PROMPT_LENS = (4, 9, 6, 12)
SERVE_CTX = 4096                  # deployment context, byte constants
PARTITION_MESHES = (8, 64, 512)   # dry-run matrix for mesh_matrix


def partition_dry_run(archs) -> dict:
    """Per-device decode columns from the abstract-mesh dry-run matrix.

    Runs ``python -m repro.analysis --mesh 8 --mesh 64 --mesh 512
    --partition-only`` in a subprocess (it must force the host CPU
    devices before jax initializes — this process's timed engines keep
    their own 2-device topology) and reduces each partition unit to the
    two per-device columns.  Returns ``{(arch, backend): {str(N):
    {"static_per_device_bytes", "collective_bytes"}}}``; empty on
    failure (the columns then read ``None`` — the bench never fails on
    the dry-run itself, the analysis CI gate owns its findings).
    """
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "partition.json")
        cmd = [sys.executable, "-m", "repro.analysis", "--partition-only",
               "--partition-archs", *archs, "--json", out]
        for n in PARTITION_MESHES:
            cmd += ["--mesh", str(n)]
        # drop this process's forced 2-device flag so the subprocess can
        # force the full matrix's device count itself
        env = dict(os.environ)
        env["XLA_FLAGS"] = " ".join(
            f for f in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in f)
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        if not os.path.exists(out):
            print(f"partition dry-run produced no JSON "
                  f"(exit {proc.returncode}):\n{proc.stderr[-2000:]}",
                  file=sys.stderr)
            return {}
        units = json.load(open(out)).get("partition", {})
    cols = {}
    for label, u in units.items():
        arch, mode, meshN = label.split("/")
        cols.setdefault((arch, mode), {})[meshN.removeprefix("mesh")] = {
            "static_per_device_bytes": sum(u["bill"]["per_device"].values()),
            "collective_bytes": sum(
                row["wire_bytes_per_device"]
                for row in u["ledger"].get("decode", ())),
        }
    return cols


def trace_rtc_columns(trace: PageAccessTrace, table, smoke) -> tuple:
    """(trace_rtc, trace_vs_analytic) for one engine's measured trace.

    The module is sized to the engine's own pools + smoke weights
    (``fitting_spec``) — a trace-scale study; the *policies* are what
    is compared, not absolute module size.  The cross-check replays the
    row-major placement's mean per-window touched-row count through the
    affine ``simulate`` — FULL_RTC's explicit count depends only on the
    per-window accessed-row count inside the allocation, so the two
    access models must agree up to the rounding of that mean.
    """
    geoms = table.stream_geometries()
    pbytes = smoke.param_counts()["total"] * _ITEMSIZE[smoke.dtype]
    spec = fitting_spec(geoms, param_bytes=pbytes)
    cols, cross = {}, None
    for pol in PLACEMENT_POLICIES:
        pl = build_placement(pol, spec, geoms, param_bytes=pbytes)
        masks = window_masks(trace, pl)
        res = simulate_trace(spec, Variant.FULL_RTC, masks=masks,
                             alloc_lo=pl.alloc_lo, alloc_rows=pl.alloc_rows)
        assert res.violations == 0, (pol, res)
        cols[pol] = {
            "refresh_savings": res.refresh_savings,
            "alloc_rows": pl.alloc_rows,
            "rows_used": pl.rows_used(),
            "mean_rows_touched": float(masks.sum(axis=1).mean()),
        }
        if pol == "row-major":
            acc = int(round(masks.sum(axis=1).mean()))
            affine = simulate(
                spec, Variant.FULL_RTC, alloc_rows=pl.alloc_rows,
                rows_accessed_per_window=acc, n_windows=masks.shape[0],
                alloc_lo=pl.alloc_lo)
            delta = abs(affine.refresh_savings - res.refresh_savings)
            cross = {
                "trace_savings": res.refresh_savings,
                "affine_savings": affine.refresh_savings,
                "delta": delta,
                "match": bool(delta <= 0.01),
            }
    return cols, cross


def sweep_arch(arch: str, max_batch: int, new_tokens: int,
               page_size: int) -> list:
    smoke = get_config(arch, smoke=True)
    model = TransformerLM(smoke)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, smoke.vocab_size, (n,)).astype(np.int32)
               for n in PROMPT_LENS]
    traffic = TrafficModel.from_config(get_config(arch), max_len=SERVE_CTX,
                                       page_size=page_size)
    rows, outs, traces = [], {}, {}
    engine_len = 16 + new_tokens
    variants = [("gather", None), ("pallas_paged", None)]
    if len(jax.devices()) >= 2:
        # the shard_map row: slots and pool extents pinned per device on
        # a (data=2, model=1) mesh; the engine auto-selects shards=2
        # from the default (divisible) pool geometry
        from jax.sharding import Mesh

        from repro.dist.sharding import ShardingPolicy
        mesh = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                    ("data", "model"))
        variants.append(("pallas_paged", mesh))
    for backend, mesh in variants:
        kw = {}
        if mesh is not None:
            kw = dict(mesh=mesh, policy=ShardingPolicy.for_mesh(mesh))
        engine = ServeEngine(
            model, params, max_len=engine_len, max_batch=max_batch,
            paged=PagedCacheConfig(page_size=page_size),
            decode_backend=backend, **kw)
        shards = engine._table.shards
        if mesh is not None:
            assert shards == 2, (
                f"{arch}: mesh engine resolved shards={shards}, "
                f"expected the device-local layout")
        # ctx_scale maps the smoke engine's occupancies onto SERVE_CTX
        # so the row-exact KV sweep and the (occupancy-independent)
        # gather view bytes describe the same deployment context.
        trace = PageAccessTrace(engine._table.stream_names())
        tele = ServeTelemetry(traffic, ctx_scale=SERVE_CTX / engine_len,
                              trace=trace)
        # warm the executables so steps/sec measures the loop, not
        # tracing (no telemetry -> the trace records only the timed run)
        engine.serve([prompts[0]], 2, seed=1)
        outs[(backend, shards)] = engine.serve(prompts, new_tokens, seed=7,
                                               telemetry=tele)
        traces[(backend, shards)] = trace
        trace_rtc, trace_cross = trace_rtc_columns(trace, engine._table,
                                                   smoke)
        n = max(tele.decode_steps, 1)
        # static audit of the exact decode executable this sweep timed
        # (smoke scale, full occupancy) — the agreement bit is the
        # trajectory signal that accounting has not drifted, and on the
        # shard_map row that per-shard bytes x shards bills exactly
        audit = decode_traffic_report(unit_from_engine(engine, arch))
        rows.append({
            "arch": arch,
            "batch": max_batch,
            "backend": backend,
            "shards": shards,
            "decode_steps": tele.decode_steps,
            "steps_per_sec": (tele.decode_steps / tele.decode_time_s
                              if tele.decode_time_s > 0 else 0.0),
            "tok_per_sec": tele.decode_tok_per_s,
            "kv_read_bytes_per_step": tele.kv_read_bytes_total // n,
            "gather_bytes_per_step": (tele.gather_read_bytes_total
                                      + tele.gather_write_bytes_total) // n,
            "static_bytes_per_step": sum(
                audit["derived"].get(k, 0) for k in audit["expected"]),
            "static_classes": {k: audit["derived"].get(k, 0)
                               for k in sorted(audit["expected"])},
            "static_match": bool(audit["match"]),
            "page_size": page_size,
            "trace_rtc": trace_rtc,
            "trace_vs_analytic": trace_cross,
        })
    ref = outs[("gather", 1)]
    for key, got in outs.items():
        if key == ("gather", 1):
            continue
        for i, (a, b) in enumerate(zip(ref, got)):
            np.testing.assert_array_equal(
                a, b, err_msg=f"{arch} request {i}: {key} generations "
                              f"diverged from gather")
    # page residency is pure scheduling — every backend on the same
    # workload must produce the identical page-access trace (the
    # solo/shard_map allocators differ in extent layout, so only the
    # solo rows are compared step for step)
    ref_steps = traces[("gather", 1)].steps
    for key, tr in traces.items():
        if key[1] != 1 or key == ("gather", 1):
            continue
        assert tr.steps == ref_steps, (
            f"{arch}: {key} page trace diverged from gather")
    rows.append(sweep_sharing(arch, model, params, smoke, traffic,
                              max_batch, new_tokens, page_size, engine_len))
    return rows


def sweep_sharing(arch, model, params, smoke, traffic, max_batch,
                  new_tokens, page_size, engine_len) -> dict:
    """The v6 prefix-sharing row: shared engine vs unshared twin.

    Same-prefix workload (duplicate + strict prefix + unique), gather
    backend, solo.  The twin serves the identical prompts with sharing
    off; generations are asserted bit-identical, the telemetry
    exact-sum invariant (hit + written == twin's total) is asserted,
    and the trace's per-step page totals may only shrink.
    """
    rng = np.random.default_rng(1)
    base = rng.integers(0, smoke.vocab_size, (12,)).astype(np.int32)
    prompts = [base, base.copy(), base[:9].copy(),
               rng.integers(0, smoke.vocab_size, (5,)).astype(np.int32)]

    def run(sharing):
        engine = ServeEngine(
            model, params, max_len=engine_len, max_batch=max_batch,
            paged=PagedCacheConfig(page_size=page_size, sharing=sharing),
            decode_backend="gather")
        trace = PageAccessTrace(engine._table.stream_names())
        tele = ServeTelemetry(traffic, ctx_scale=SERVE_CTX / engine_len,
                              trace=trace)
        engine.serve([prompts[-1]], 2, seed=1)      # warm the executables
        out = engine.serve(prompts, new_tokens, seed=7, telemetry=tele)
        return engine, tele, trace, out

    _, _, twin_trace, twin_out = run(None)
    engine, tele, trace, out = run(PrefixSharingConfig())
    for i, (a, b) in enumerate(zip(twin_out, out)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"{arch} request {i}: shared-prefix generation "
                          f"diverged from the unshared twin")
    shared_pages = sum(trace.step_page_counts())
    twin_pages = sum(twin_trace.step_page_counts())
    assert shared_pages <= twin_pages, (
        f"{arch}: sharing grew the trace row set "
        f"({shared_pages} > {twin_pages})")
    n = max(tele.decode_steps, 1)
    audit = decode_traffic_report(unit_from_engine(engine, arch))
    trace_rtc, trace_cross = trace_rtc_columns(trace, engine._table, smoke)
    return {
        "arch": arch,
        "batch": max_batch,
        "backend": "gather",
        "shards": engine._table.shards,
        "decode_steps": tele.decode_steps,
        "steps_per_sec": (tele.decode_steps / tele.decode_time_s
                          if tele.decode_time_s > 0 else 0.0),
        "tok_per_sec": tele.decode_tok_per_s,
        "kv_read_bytes_per_step": tele.kv_read_bytes_total // n,
        "gather_bytes_per_step": (tele.gather_read_bytes_total
                                  + tele.gather_write_bytes_total) // n,
        "static_bytes_per_step": sum(
            audit["derived"].get(k, 0) for k in audit["expected"]),
        "static_classes": {k: audit["derived"].get(k, 0)
                           for k in sorted(audit["expected"])},
        "static_match": bool(audit["match"]),
        "page_size": page_size,
        "trace_rtc": trace_rtc,
        "trace_vs_analytic": trace_cross,
        "prefix": {
            "hit_bytes": tele.prefix_hit_bytes_total,
            "admit_write_bytes": tele.admit_write_bytes_total,
            "cow_bytes": (tele.cow_read_bytes_total
                          + tele.cow_write_bytes_total),
            "hit_pages": engine._table.stats["pages_attached"],
            "full_skips": tele.prefix_full_skips,
            "savings_frac": tele.prefix_hit_frac,
            "trace_step_pages": shared_pages,
            "twin_step_pages": twin_pages,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(DEFAULT_ARCHS),
                    help="comma-separated arch ids, or 'all'")
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()
    archs = ARCH_IDS if args.archs == "all" else \
        tuple(a.strip() for a in args.archs.split(",") if a.strip())

    rows = []
    for arch in archs:
        rows.extend(sweep_arch(arch, args.max_batch, args.new_tokens,
                               args.page_size))
    per_device = partition_dry_run(archs)
    for r in rows:
        r.setdefault("prefix", None)     # baseline variants: sharing OFF
        matrix = per_device.get((r["arch"], r["backend"]))
        r["mesh_matrix"] = matrix if matrix else None
    for r in rows:
        us = 1e6 / r["steps_per_sec"] if r["steps_per_sec"] else 0.0
        m8 = (r["mesh_matrix"] or {}).get("8") or {}
        tr = r["trace_rtc"]
        px = r["prefix"]
        emit(f"serve_decode_{r['arch']}_{r['backend']}"
             + (f"_sm{r['shards']}" if r["shards"] > 1 else "")
             + ("_prefix" if px is not None else ""), us,
             f"steps/s={r['steps_per_sec']:.2f} "
             f"kv_read/step={r['kv_read_bytes_per_step']} "
             f"gather/step={r['gather_bytes_per_step']} "
             f"static/step={r['static_bytes_per_step']} "
             f"perdev@8={m8.get('static_per_device_bytes')} "
             f"collective/dev@8={m8.get('collective_bytes')} "
             f"trace_rtc[rm/bi/sc]="
             + "/".join(f"{tr[p]['refresh_savings']:.3f}"
                        for p in PLACEMENT_POLICIES)
             + (f" prefix_hit={px['savings_frac']:.3f} "
                f"skips={px['full_skips']}" if px is not None else "")
             + f" audit={'ok' if r['static_match'] else 'DRIFT'}")
    if not all(r["static_match"] for r in rows):
        raise SystemExit("static audit disagrees with telemetry — "
                         "run python -m repro.analysis for the class diff")
    if not any(r["shards"] > 1 for r in rows):
        raise SystemExit("no shard_map row was swept — the forced "
                         "2-device topology did not take effect")
    if not all(r["trace_vs_analytic"]["match"] for r in rows):
        bad = [(r["arch"], r["backend"], r["trace_vs_analytic"])
               for r in rows if not r["trace_vs_analytic"]["match"]]
        raise SystemExit(f"trace-driven refresh savings diverged from the "
                         f"affine model on equivalent inputs: {bad}")
    px_rows = [r["prefix"] for r in rows if r["prefix"] is not None]
    if not px_rows:
        raise SystemExit("no prefix-sharing row was swept")
    if not any(p["hit_bytes"] > 0 and p["full_skips"] >= 1
               for p in px_rows):
        raise SystemExit(
            "no swept arch realized prefix hits + a full prefill skip — "
            f"the sharing path regressed: {px_rows}")
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump({"schema": "serve-decode-v6", "rows": rows}, f, indent=1)
    print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
