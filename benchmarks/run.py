"""Benchmark driver: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` prints
``name,us_per_call,derived`` CSV rows for:
  fig1   — system energy breakdown (refresh shares)
  fig10  — RTC variant savings grid (RTT/PAAR/full/mid/min)
  fig11  — RTC vs SmartRefresh
  fig12  — refresh share vs chip density
  fig13  — Eigenfaces / BCPNN / BFAST
  lm_rtc — beyond-paper: RTC on the 10 assigned LM archs
  sim    — event-level simulator cross-check (integrity + agreement)
  roofline — dry-run roofline table (requires cached dry-run results)
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import sys


def _sim_crosscheck():
    from benchmarks.common import emit, timed
    from repro.core.dram import DRAMSpec
    from repro.core.refresh_sim import simulate
    from repro.core.rtc import Variant

    spec = DRAMSpec(capacity_bytes=65536 * 2048)

    def run():
        r = simulate(spec, Variant.FULL_RTC, alloc_rows=16384,
                     rows_accessed_per_window=4096, n_windows=16)
        expected = 1.0 - (16384 - 4096) / 65536
        return r, expected

    (r, expected), us = timed(run, repeat=1)
    emit("sim_fullrtc_vs_analytic", us,
         f"sim={r.refresh_savings:.4f} analytic={expected:.4f} "
         f"violations={r.violations}")


def main() -> None:
    from benchmarks import (fig1_breakdown, fig10_savings, fig11_smartrefresh,
                            fig12_scaling, fig13_other_apps, lm_rtc, roofline)
    print("name,us_per_call,derived")
    fig1_breakdown.main()
    fig10_savings.main()
    fig11_smartrefresh.main()
    fig12_scaling.main()
    fig13_other_apps.main()
    lm_rtc.main()
    _sim_crosscheck()
    try:
        roofline.main()
    except Exception as e:  # dry-run cache may not exist yet
        print(f"roofline,,skipped ({e})", file=sys.stderr)


if __name__ == "__main__":
    main()
