"""Paper Fig. 1: system-level energy breakdown of three CNNs.

Validates: refresh ~= 15% of system energy for AlexNet/GoogleNet and
~= 47% for LeNet on a 2 GB-DRAM Eyeriss-class accelerator.
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

from benchmarks.common import emit, save_json, timed
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import MODULE_2GB
from repro.core.energy import system_power
from repro.core.workload import from_cnn

PAPER_SHARES = {"alexnet": 0.15, "googlenet": 0.15, "lenet": 0.47}


def run():
    rows = {}
    for name, prof in CNN_ZOO.items():
        w = from_cnn(prof, fps=60)
        sp = system_power(MODULE_2GB, w, prof.macs_per_frame * 60)
        rows[name] = {
            "refresh_share": sp["refresh_share"],
            "dram_share": sp["dram_share"],
            "paper_refresh_share": PAPER_SHARES[name],
        }
    return rows


def main():
    rows, us = timed(run)
    for name, r in rows.items():
        emit(f"fig1_{name}_refresh_share", us / len(rows),
             f"{r['refresh_share']:.3f} (paper {r['paper_refresh_share']:.2f})")
    save_json("fig1_breakdown", rows)


if __name__ == "__main__":
    main()
