"""Paper Fig. 10 (a-f): DRAM energy savings of RTC variants.

Full grid: {full,mid,min}-RTC x {AN,LN,GN} x {30,60} fps x
{2,4,8} GB x {100%,50%} locality, with RTT / PAAR / combined bars.

Validates (paper text anchors):
  * Full-RTC AN@60fps/2GB: RTT ~44%, AN@30fps: ~30%;
  * Full-RTC LN: ~96% (via PAAR);
  * Full-RTC picks max(RTT, PAAR) per workload;
  * Min-RTC up to ~20% @2GB for AN/GN, decreasing with capacity;
  * overall refresh-energy reduction range ~25%..96+%.
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

from benchmarks.common import emit, save_json, timed
from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import EVAL_MODULES
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.workload import from_cnn

VARIANTS = (Variant.FULL_RTC, Variant.MID_RTC, Variant.MIN_RTC,
            Variant.FULL_RTC_PLUS)


def run():
    grid = []
    for cap, spec in EVAL_MODULES.items():
        for cnn, prof in CNN_ZOO.items():
            for fps in (30, 60):
                for loc in (1.0, 0.5):
                    w = from_cnn(prof, fps, locality=loc)
                    alloc = allocate_workload(
                        spec, {"data": w.footprint_bytes})
                    rtt, paar = rtt_paar_split(spec, w, alloc)
                    row = {
                        "dram": cap, "cnn": cnn, "fps": fps,
                        "locality": loc, "rtt": rtt, "paar": paar,
                    }
                    for var in VARIANTS:
                        rep = evaluate(spec, w, var, alloc)
                        row[var.value] = rep.dram_savings
                        row[var.value + "_refresh"] = rep.refresh_savings
                    grid.append(row)
    return grid


def main():
    grid, us = timed(run, repeat=1)
    per = us / len(grid)
    for row in grid:
        if row["dram"] == "2GB" and row["locality"] == 1.0:
            emit(
                f"fig10a_{row['cnn']}_{row['fps']}fps", per,
                f"rtt={row['rtt']:.3f} paar={row['paar']:.3f} "
                f"full={row['full-rtc']:.3f} mid={row['mid-rtc']:.3f} "
                f"min={row['min-rtc']:.3f}")
    # the paper's 25%..96% range spans the least (min-RTC) to the most
    # (full-RTC) aggressive design across CNNs/capacities
    all_refresh = [r[v.value + "_refresh"] for r in grid
                   for v in (Variant.MIN_RTC, Variant.MID_RTC,
                             Variant.FULL_RTC)]
    nonzero = [v for v in all_refresh if v > 0.01]
    emit("fig10_refresh_savings_range", per,
         f"{min(nonzero):.2f}..{max(nonzero):.2f} (paper 0.25..0.96)")
    save_json("fig10_savings", grid)


if __name__ == "__main__":
    main()
