"""Render §Roofline markdown from the cached dry-run JSONs."""
from __future__ import annotations

import glob
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["gemma-2b", "smollm-360m", "gemma2-9b", "qwen1.5-0.5b",
              "mixtral-8x22b", "dbrx-132b", "internvl2-1b",
              "falcon-mamba-7b", "recurrentgemma-2b", "musicgen-medium"]


def load(mesh="pod", tag="baseline"):
    out = {}
    for p in glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}__{tag}.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_t(x):
    return f"{x*1e3:.1f}" if x >= 1e-4 else f"{x*1e3:.2f}"


def main(mesh="pod", tag="baseline"):
    recs = load(mesh, tag)
    print("| arch | shape | compute ms | memory ms | coll ms | dominant "
          "| useful | MFU bound | peak GiB | fits | knobs |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                print(f"| {arch} | {shape} | — | — | — | *(pending)* "
                      "| | | | | |")
                continue
            if r.get("skipped"):
                print(f"| {arch} | {shape} | — | — | — | *skip: "
                      "full-attention @512k* | | | | | |")
                continue
            t = r["terms_s"]
            o = r.get("opts", {})
            knobs = []
            if o.get("microbatch", 1) > 1:
                knobs.append(f"mb{o['microbatch']}")
            if o.get("fsdp"):
                knobs.append("fsdp")
            if o.get("opt_state_dtype") == "bfloat16":
                knobs.append("bf16-mom")
            print(
                f"| {arch} | {shape} | {fmt_t(t['compute_s'])} "
                f"| {fmt_t(t['memory_s'])} | {fmt_t(t['collective_s'])} "
                f"| {r['dominant'].replace('_s','')} "
                f"| {r['useful_compute_ratio']:.2f} "
                f"| {r['mfu_bound']:.3f} "
                f"| {r['peak_bytes_per_device']/2**30:.1f} "
                f"| {'yes' if r['fits_hbm'] else '**no**'} "
                f"| {','.join(knobs)} |")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
