"""Roofline table generator (§Roofline of EXPERIMENTS.md).

Reads the cached dry-run records and emits, per (arch x shape), the
three terms, the dominant bottleneck, MODEL_FLOPS ratio, and the
one-line "what would move the dominant term" note.
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

import glob
import json
import os

from benchmarks.common import emit, save_json

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

NOTES = {
    ("compute_s", "train"): "raise per-chip work: bigger microbatch / "
        "drop remat recompute (remat=dots) / fix head-padding idle chips",
    ("compute_s", "prefill"): "head-padding idle chips; flash kernel "
        "fuses the softmax pipeline on real TPUs",
    ("compute_s", "decode"): "batch more sequences per chip",
    ("memory_s", "train"): "cut activation re-materialization: remat=dots, "
        "fuse CE chunks, avoid GQA K/V expansion",
    ("memory_s", "prefill"): "avoid GQA K/V expansion; fuse attention "
        "(flash kernel) to stop spilling score tiles",
    ("memory_s", "decode"): "decode is KV-cache-bandwidth bound by nature: "
        "quantize cache / widen batch to amortize weight reads",
    ("collective_s", "train"): "reduce-scatter instead of all-reduce for "
        "grads (ZeRO-1), overlap collectives with compute, CE label "
        "gather via one-hot einsum",
    ("collective_s", "prefill"): "keep activations sequence-sharded "
        "between attention and MLP (sequence parallelism)",
    ("collective_s", "decode"): "shard KV on heads where possible; "
        "all-reduce only the 1-token logits",
}


def rows(tag: str = "baseline", mesh: str = "pod"):
    out = []
    for path in sorted(glob.glob(
            os.path.join(DRYRUN_DIR, f"*__{mesh}__{tag}.json"))):
        rec = json.load(open(path))
        if rec.get("skipped"):
            out.append(rec)
            continue
        kind = ("train" if rec["shape"].startswith("train") else
                "prefill" if rec["shape"].startswith("prefill") else
                "decode")
        rec["note"] = NOTES.get((rec["dominant"], kind), "")
        out.append(rec)
    return out


def main():
    table = rows()
    for rec in table:
        key = f"roofline_{rec['arch']}__{rec['shape']}"
        if rec.get("skipped"):
            emit(key, 0.0, "skipped: " + rec["reason"][:50])
            continue
        t = rec["terms_s"]
        emit(key, rec.get("compile_s", 0.0) * 1e6,
             f"compute={t['compute_s']*1e3:.2f}ms "
             f"memory={t['memory_s']*1e3:.2f}ms "
             f"coll={t['collective_s']*1e3:.2f}ms "
             f"dom={rec['dominant'].replace('_s','')} "
             f"useful={rec['useful_compute_ratio']:.2f} "
             f"mfu_bound={rec['mfu_bound']:.3f} "
             f"fits={rec['fits_hbm']}")
    save_json("roofline_table", table)


if __name__ == "__main__":
    main()
