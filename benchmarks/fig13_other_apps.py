"""Paper Fig. 13: RTC beyond CNNs — Eigenfaces, BCPNN, BFAST.

Per Section VI-E:
  * Eigenfaces — streaming multi-stage filter, 1024x1024x3 @60 fps,
    re-reads its data several times per frame: RTT *and* PAAR help;
  * BCPNN — touches its entire (huge) allocation 4x per iteration:
    RTT eliminates refresh, PAAR useless (everything allocated);
  * BFAST — random access (Smith-Waterman index walks): not
    AGU-expressible, RTC bypassed, ~0 savings.
"""
from __future__ import annotations

if __package__ in (None, ""):
    import _bootstrap  # noqa: F401  (direct invocation: sys.path setup)

from benchmarks.common import emit, save_json, timed
from repro.core.allocator import allocate_workload
from repro.core.dram import GiB, MODULE_8GB, module
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.workload import WorkloadProfile


def apps(spec):
    img = 1024 * 1024 * 3 * 4
    yield WorkloadProfile(
        name="eigenfaces", footprint_bytes=64 * img,
        iter_period_s=1 / 60,
        read_bytes_per_iter=4 * img, write_bytes_per_iter=img,
        regular=True)
    # BCPNN scaled to module capacity (paper: 30 TB across a cluster;
    # per-module slice is fully allocated, read 4x per ~1 s iteration)
    cap = int(spec.capacity_bytes * 0.9)
    yield WorkloadProfile(
        name="bcpnn", footprint_bytes=cap, iter_period_s=0.05,
        read_bytes_per_iter=cap // 5, write_bytes_per_iter=cap // 20,
        regular=True)
    # BFAST fills the module with its genome index (random-access walks
    # over ~all of it): neither RTT (irregular) nor PAAR (allocated)
    # applies — "the RTC circuitry is bypassed" (Section VI-E).
    yield WorkloadProfile(
        name="bfast", footprint_bytes=int(spec.capacity_bytes * 0.98),
        iter_period_s=0.1,
        read_bytes_per_iter=2 * GiB // 10, write_bytes_per_iter=0,
        regular=False)  # random access: AGU cannot express


def run():
    rows = []
    for cap_gb in (2, 4, 8):
        spec = module(cap_gb)
        for w in apps(spec):
            alloc = allocate_workload(spec, {"data": w.footprint_bytes})
            rep = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
            rtt, paar = rtt_paar_split(spec, w, alloc)
            rows.append({
                "app": w.name, "dram_gb": cap_gb,
                "rtt": rtt, "paar": paar,
                "rtc_savings": rep.dram_savings,
                "refresh_savings": rep.refresh_savings,
            })
    return rows


def main():
    rows, us = timed(run, repeat=1)
    for r in rows:
        emit(f"fig13_{r['app']}_{r['dram_gb']}GB", us / len(rows),
             f"rtc={r['rtc_savings']:.3f} rtt={r['rtt']:.3f} "
             f"paar={r['paar']:.3f}")
    save_json("fig13_other_apps", rows)


if __name__ == "__main__":
    main()
