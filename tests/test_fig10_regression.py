"""Regression pin for the Fig. 10 savings grid (benchmarks/fig10_savings).

Two layers of assertion on the per-CNN **full-RTC DRAM energy
savings** at 2 GB / locality 1.0 (the Fig. 10a column):

* a tight pin (±0.02) on the CURRENT calibration, so silent drift in
  the energy/allocator models is caught by CI;
* a documented band around the paper's text-anchored values where the
  paper states one (Section VI: AlexNet@60fps ~44% via RTT, LeNet ~96%
  via PAAR).  GoogLeNet and the 30 fps AlexNet point have no numeric
  text anchor; they are pinned to calibration only.

The benchmark's printed refresh-savings *range* currently spans
0.01..1.00 against the paper's quoted 25%..96% — the low end comes
from min-RTC at large capacities (savings shrink with capacity, as the
paper notes), the high end from full-RTC eliminating every refresh of
a fully re-accessed allocation.  The per-CNN pins below are the
calibration-sensitive quantities.
"""
import pytest

from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import EVAL_MODULES
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.workload import from_cnn

# (cnn, fps) -> (current calibration, paper Fig. 10 anchor or None)
EXPECTED_FULL_RTC_2GB = {
    ("alexnet", 30): (0.551, None),
    ("alexnet", 60): (0.426, 0.44),
    ("lenet", 30): (0.973, 0.96),
    ("lenet", 60): (0.971, 0.96),
    ("googlenet", 30): (0.834, None),
    ("googlenet", 60): (0.741, None),
}
CALIBRATION_TOL = 0.02
PAPER_TOL = 0.05


def _full_rtc(cnn: str, fps: int):
    spec = EVAL_MODULES["2GB"]
    w = from_cnn(CNN_ZOO[cnn], fps, locality=1.0)
    alloc = allocate_workload(spec, {"data": w.footprint_bytes})
    rep = evaluate(spec, w, Variant.FULL_RTC, alloc)
    rtt, paar = rtt_paar_split(spec, w, alloc)
    return rep.dram_savings, rtt, paar


@pytest.mark.parametrize("cnn,fps", sorted(EXPECTED_FULL_RTC_2GB))
def test_full_rtc_savings_pinned(cnn, fps):
    got, _, _ = _full_rtc(cnn, fps)
    current, paper = EXPECTED_FULL_RTC_2GB[(cnn, fps)]
    assert got == pytest.approx(current, abs=CALIBRATION_TOL), (
        f"{cnn}@{fps}fps full-RTC drifted from the pinned calibration: "
        f"{got:.3f} vs {current:.3f}")
    if paper is not None:
        assert got == pytest.approx(paper, abs=PAPER_TOL), (
            f"{cnn}@{fps}fps full-RTC left the paper's Fig. 10 band: "
            f"{got:.3f} vs paper {paper:.2f}")


@pytest.mark.parametrize("cnn,fps", sorted(EXPECTED_FULL_RTC_2GB))
def test_full_rtc_is_max_of_rtt_paar(cnn, fps):
    """Paper: full-RTC picks the better of RTT and PAAR per workload."""
    got, rtt, paar = _full_rtc(cnn, fps)
    assert got == pytest.approx(max(rtt, paar), abs=1e-6)
