"""Multi-device serving: the mesh/policy path CI never used to exercise.

``XLA_FLAGS=--xla_force_host_platform_device_count=2`` must be set
before jax initializes, so the check runs in a subprocess: a 2-device
(data=2, model=1) mesh engine serves a mixed-length batched workload
and must reproduce a single-device solo engine bit-for-bit.  This
covers the sharded prefill/decode builders end to end — including the
batch-1 prefill (replicated batch dim: a size-1 dim cannot be laid out
over a 2-device data axis) and the cache-sharding round trip through
slot insertion, both of which were broken before length-bucketed
prefill landed because nothing ever ran the engine on >1 device.
"""
import os
import subprocess
import sys

import pytest

# CI runs this module in the serve-smoke job (it spawns a subprocess
# engine sweep); the tier-1 jobs deselect it with -m "not slow_serve".
pytestmark = pytest.mark.slow_serve

_SCRIPT = r"""
import os
# the forced device count only applies to the host (CPU) platform --
# pin it so a GPU/TPU jax install doesn't grab its own backend instead
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.dist.sharding import ShardingPolicy
from repro.models.transformer import TransformerLM
from repro.serve import PagedCacheConfig, ServeEngine

assert len(jax.devices()) == 2, jax.devices()
cfg = get_config("qwen1.5-0.5b", smoke=True)
model = TransformerLM(cfg)
params = model.init(jax.random.key(0))

mesh = Mesh(np.array(jax.devices()).reshape(2, 1), ("data", "model"))
policy = ShardingPolicy.for_mesh(mesh)
meshed = ServeEngine(model, params, max_len=32, max_batch=2,
                     mesh=mesh, policy=policy)
solo = ServeEngine(model, params, max_len=32, max_batch=1)

rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
           for n in (5, 9, 3)]
# greedy AND per-request stochastic params, through the 2-device mesh
temps, topks = [0.0, 50.0, 50.0], [None, None, 5]
out_mesh = meshed.serve(prompts, 5, temperature=temps, top_k=topks, seed=7)
out_solo = solo.serve(prompts, 5, temperature=temps, top_k=topks, seed=7)
for i, (a, b) in enumerate(zip(out_mesh, out_solo)):
    np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
assert meshed.prefill_executables <= len(meshed.buckets.ladder)

# paged cache on the same mesh with a per-shard budget BELOW the
# 4-page slot floor (6 resident pages / 2 devices = 3): the engine must
# fall back to the single-pool GSPMD layout, and a budget tight enough
# to force offload mid-serve must still reproduce solo bit-for-bit
paged = ServeEngine(model, params, max_len=32, max_batch=2,
                    mesh=mesh, policy=policy,
                    paged=PagedCacheConfig(page_size=8, resident_pages=6))
assert paged._table.shards == 1, paged._table.shards
out_paged = paged.serve(prompts, 12, temperature=temps, top_k=topks, seed=7)
out_ref = solo.serve(prompts, 12, temperature=temps, top_k=topks, seed=7)
for i, (a, b) in enumerate(zip(out_paged, out_ref)):
    np.testing.assert_array_equal(a, b, err_msg=f"paged request {i}")

# block-table Pallas decode kernel on the same mesh: the default pool
# splits evenly (8 resident pages / 2 devices clears the slot floor),
# so the engine auto-selects the device-local layout and the kernel
# runs inside shard_map against its device's own pool extent — no
# GSPMD gather around the opaque call — and must reproduce the solo
# kernel engine bit-for-bit
kernel_kw = dict(max_len=32, max_batch=2,
                 paged=PagedCacheConfig(page_size=8),
                 decode_backend="pallas_paged")
kernel_mesh = ServeEngine(model, params, mesh=mesh, policy=policy,
                          **kernel_kw)
assert kernel_mesh._table.shards == 2, kernel_mesh._table.shards
kernel_solo = ServeEngine(model, params, **kernel_kw)
out_km = kernel_mesh.serve(prompts, 12, temperature=temps, top_k=topks, seed=7)
out_ks = kernel_solo.serve(prompts, 12, temperature=temps, top_k=topks, seed=7)
for i, (a, b) in enumerate(zip(out_km, out_ks)):
    np.testing.assert_array_equal(a, b, err_msg=f"kernel request {i}")

# device-local shard_map decode under pool pressure: 2 slots + 4
# resident pages pinned to each device (max_batch 4, resident 8 on
# data=2).  One shard's two live slots need 3 pages each against its
# 4-page extent, forcing preemption, host offload and cross-shard
# restore mid-serve — and the generations must STILL match the
# ample-budget solo engine bit-for-bit.
from repro.serve.telemetry import ServeTelemetry, TrafficModel
local = ServeEngine(model, params, max_len=32, max_batch=4,
                    mesh=mesh, policy=policy,
                    paged=PagedCacheConfig(page_size=8, resident_pages=8))
assert local._table.shards == 2, local._table.shards
tel = ServeTelemetry(TrafficModel.from_config(cfg, 32, page_size=8))
out_local = local.serve(prompts, 12, temperature=temps, top_k=topks,
                        seed=7, telemetry=tel)
assert tel.page_outs > 0, "per-shard pool pressure never forced an offload"
for i, (a, b) in enumerate(zip(out_local, out_ref)):
    np.testing.assert_array_equal(a, b, err_msg=f"shard_map request {i}")
print("MULTIDEVICE_SERVE_OK", flush=True)
"""


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH")) if p)
    return env


def test_two_device_mesh_serve_matches_solo():
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=_env(),
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"multi-device serve failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr[-4000:]}")
    assert "MULTIDEVICE_SERVE_OK" in proc.stdout


def test_static_analyzer_is_collective_free_and_gate_passes():
    """The static auditor across mesh 2/8/64: the device-local
    shard_map decode layout must audit CLEAN — no GSPMD gather around
    the opaque paged-attention kernel, zero ``pool-collective``
    findings at any audited mesh size — against an EMPTY baseline, so
    the gate exiting 0 proves the findings are gone, not allowlisted.
    Any pool page moving cross-device at any mesh size fails here.

    ``python -m repro.analysis`` forces the CPU device topology itself,
    which is why this runs as a subprocess like the serve test.
    """
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--check-baseline",
         "--archs", "qwen1.5-0.5b",
         "--mesh", "2", "--mesh", "8", "--mesh", "64"],
        env=_env(), capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"analysis gate failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr[-4000:]}")
    assert "analysis gate: OK" in proc.stdout
    assert "gspmd-gather-around-pallas-call" not in proc.stdout, proc.stdout
    assert "pool-collective" not in proc.stdout, proc.stdout
    # no errors at all, and none silently absorbed by a baseline entry
    assert proc.stdout.count("[error]") == 0, proc.stdout
    assert "0/0 baselined finding(s) in scope" in proc.stdout, proc.stdout
