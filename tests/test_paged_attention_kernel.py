"""Parity suite for the block-table paged decode-attention kernel.

Contract under test, at three altitudes:

* **op level** — ``paged_attention(backend="pallas")`` (the Pallas
  kernel, interpret mode) matches ``backend="ref"`` (gather + dense
  softmax) over page sizes that do and don't divide the cache length
  (partial tail pages), ring wrap-around, per-slot positions, sliding
  windows (including windows smaller than one page), and softcap.
* **model level** — ``decode_step(..., decode_backend="pallas_paged")``
  on a paged cache tracks both the gather backend and the contiguous
  cache across lockstep greedy decoding on ALL 10 archs: logits agree
  to interpret-mode accumulation tolerance (the kernel's online
  softmax sums pages sequentially; the gather path reduces over the
  full row — documented, not a defect) and the sampled tokens are
  IDENTICAL, including across page-growth boundaries and
  post-preemption (offload/restore) resume.
* **engine level** — ``ServeEngine(decode_backend="pallas_paged")``
  serves every arch with generations identical to the gather engine
  (the PR's acceptance criterion), and telemetry accounts only true
  per-page reads on the kernel path — zero materialized-view traffic.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.kernels.paged_attention.ops import paged_attention
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, PageTable, ServeEngine,
                         ServeTelemetry, TrafficModel)

# Interpret-mode tolerance: the kernel accumulates the softmax online
# over pages while the oracle reduces over the whole row at once, so
# f32 results differ by accumulation order only.
TOL = 2e-4

MAX_CTX = 24
BUCKET = 16
PAGE = 5          # deliberately not a divisor of MAX_CTX or any window


# ---------------------------------------------------------------------------
# op level: kernel vs gather oracle
# ---------------------------------------------------------------------------
OP_CASES = [
    # b, kvh, g, hd, page, cache_len, window, softcap
    (2, 2, 2, 16, 5, 24, None, None),     # partial tail page
    (3, 1, 4, 8, 3, 10, 8, 30.0),         # window + softcap, GQA 4
    (1, 2, 1, 32, 4, 16, 5, None),        # window > page? no: 5 > 4
    (2, 4, 2, 16, 2, 7, 3, None),         # window smaller than 2 pages
    (1, 1, 1, 8, 1, 6, 1, None),          # row-granular pages, window=1
    (2, 2, 3, 16, 24, 24, None, 50.0),    # one whole-cache page
]


@pytest.mark.parametrize("b,kvh,g,hd,page,L,window,softcap", OP_CASES)
def test_kernel_matches_gather_oracle(b, kvh, g, hd, page, L, window,
                                      softcap, rng):
    n_lp = -(-L // page)
    n_pages = 2 + b * n_lp + 3
    q = jnp.asarray(rng.standard_normal((b, kvh, g, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, page, kvh, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, kvh, hd)),
                     jnp.float32)
    block = jnp.asarray(
        rng.permutation(np.arange(2, n_pages))[:b * n_lp].reshape(b, n_lp),
        jnp.int32)
    # per-slot positions straddling the ring boundary (pos >= L wraps)
    pos = jnp.asarray(rng.integers(0, 2 * L, (b,)), jnp.int32)
    ref = paged_attention(q, kp, vp, block, pos, cache_len=L, window=window,
                          softcap=softcap, backend="ref")
    pal = paged_attention(q, kp, vp, block, pos, cache_len=L, window=window,
                          softcap=softcap, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_kernel_rejects_short_block_table(rng):
    q = jnp.zeros((1, 1, 1, 8), jnp.float32)
    kp = jnp.zeros((4, 4, 1, 8), jnp.float32)
    block = jnp.zeros((1, 2), jnp.int32)       # 2 pages x 4 rows < 12
    with pytest.raises(ValueError, match="block table"):
        paged_attention(q, kp, kp, block, jnp.zeros((1,), jnp.int32),
                        cache_len=12, backend="pallas")
    with pytest.raises(ValueError, match="backend"):
        paged_attention(q, kp, kp, block, jnp.zeros((1,), jnp.int32),
                        cache_len=8, backend="nope")


# ---------------------------------------------------------------------------
# model level: lockstep decode across backends, all archs
# ---------------------------------------------------------------------------
_CACHED = {}


def _arch(arch):
    """(model, params, jitted prefill, decode fns per backend, insert,
    per-backend PageTables) — cached per arch.  Each backend gets its
    OWN PageTable so its cache evolves through its own decode chain
    (separately jitted programs may fuse the K/V projection
    differently, so cross-program cache rows are close, not bitwise);
    the tables are driven with identical call sequences, so their page
    assignments are identical."""
    if arch not in _CACHED:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = model.init(jax.random.key(0))
        prefill = jax.jit(
            lambda p, t, n: model.prefill(p, t, MAX_CTX, lengths=n))
        tables = {be: PageTable(model, max_batch=2, max_ctx=MAX_CTX,
                                page_size=PAGE)
                  for be in ("gather", "pallas_paged")}
        decode = {
            be: jax.jit(functools.partial(model.decode_step,
                                          decode_backend=be))
            for be in ("gather", "pallas_paged")
        }
        _CACHED[arch] = (model, params, prefill, decode,
                         jax.jit(ServeEngine._insert_cache), tables)
    return _CACHED[arch]


def _build_pair(arch, plens):
    """Admit ``plens`` prompts into the contiguous cache and both
    backends' paged caches (slots 0..)."""
    model, params, prefill, decode, insert, tables = _arch(arch)
    cfg = model.cfg
    cache_c = model.init_cache(2, MAX_CTX)
    caches = {}
    for be, table in tables.items():
        table.reset()
        caches[be] = table.init_cache()
    toks = []
    for s, pl in enumerate(plens):
        row = np.random.default_rng(100 * pl + s).integers(
            0, cfg.vocab_size, (pl,)).astype(np.int32)
        padded = np.zeros((1, BUCKET), np.int32)
        padded[0, :pl] = row
        logits, one = prefill(params, jnp.asarray(padded),
                              jnp.asarray([pl], jnp.int32))
        cache_c = insert(cache_c, one, jnp.asarray(s, jnp.int32))
        for be, table in tables.items():
            caches[be] = table.admit(caches[be], one, s, pl)
        toks.append(int(jnp.argmax(logits[0])))
    return (model, params, decode, tables, cache_c, caches,
            np.asarray(toks, np.int32), np.asarray(plens, np.int32))


def _lockstep3(model, params, decode, tables, cache_c, caches,
               tok, pos, steps, msg):
    """Decode contiguous / paged-gather / paged-kernel in lockstep,
    each through its own cache chain.  Per step: gather logits ==
    contiguous logits bit-for-bit, kernel logits within TOL, and the
    kernel's greedy tokens IDENTICAL to the exact paths'.
    """
    tok_c = tok_g = tok_k = jnp.asarray(tok)
    for i in range(steps):
        for be, table in tables.items():
            for s in range(pos.shape[0]):
                caches[be], ok = table.prepare_step(
                    caches[be], s, int(pos[s]))
                assert ok, f"{msg}: {be} pool exhausted at step {i}"
        posj = jnp.asarray(pos)
        lc, cache_c = decode["gather"](params, cache_c, tok_c, posj)
        lg, caches["gather"] = decode["gather"](
            params, caches["gather"], tok_g, posj)
        lk, caches["pallas_paged"] = decode["pallas_paged"](
            params, caches["pallas_paged"], tok_k, posj)
        np.testing.assert_array_equal(
            np.asarray(lc), np.asarray(lg),
            err_msg=f"{msg}: step {i} gather != contiguous")
        np.testing.assert_allclose(
            np.asarray(lk), np.asarray(lg), atol=TOL, rtol=TOL,
            err_msg=f"{msg}: step {i} kernel logits")
        tok_c = jnp.argmax(lc, -1).astype(jnp.int32)
        tok_g = jnp.argmax(lg, -1).astype(jnp.int32)
        tok_k = jnp.argmax(lk, -1).astype(jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(tok_k), np.asarray(tok_g),
            err_msg=f"{msg}: step {i} kernel tokens diverged")
        pos = pos + 1
    return cache_c, caches, tok_g, pos


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_kernel_decode_all_archs(arch):
    """decode_backend='pallas_paged' tracks gather and contiguous
    decode on every arch: tokens identical, logits within TOL,
    through page growth past the prefill lengths."""
    plens = (7, 10)
    (model, params, decode, tables, cache_c, caches,
     tok, pos) = _build_pair(arch, plens)
    steps = min(8, MAX_CTX - max(plens))
    _lockstep3(model, params, decode, tables, cache_c, caches,
               tok, pos, steps, arch)


def test_kernel_decode_survives_offload_resume():
    """Post-preemption resume: offload a slot's pages to host, restore
    into different physical pages, and keep decoding through the
    kernel — tokens still match the exact paths."""
    (model, params, decode, tables, cache_c, caches,
     tok, pos) = _build_pair("qwen1.5-0.5b", (7, 10))
    cache_c, caches, tok, pos = _lockstep3(
        model, params, decode, tables, cache_c, caches,
        tok, pos, 3, "pre-offload")
    for be, table in tables.items():
        caches[be], payload = table.offload(caches[be], 1, int(pos[1]))
        caches[be] = table.restore(caches[be], 1, payload)
    _lockstep3(model, params, decode, tables, cache_c, caches,
               tok, pos, 3, "post-restore")


def test_pallas_backend_requires_paged_cache():
    model, params, *_ = _arch("qwen1.5-0.5b")
    cache = model.init_cache(1, 8)
    step = functools.partial(model.decode_step,
                             decode_backend="pallas_paged")
    with pytest.raises(ValueError, match="pallas_paged"):
        step(params, cache, jnp.zeros((1,), jnp.int32),
             jnp.zeros((1,), jnp.int32))
    with pytest.raises(ValueError, match="decode backend"):
        model.decode_step(params, cache, jnp.zeros((1,), jnp.int32),
                          jnp.zeros((1,), jnp.int32),
                          decode_backend="typo")


# ---------------------------------------------------------------------------
# engine level: all archs, generations identical (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.slow_serve
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_engine_kernel_backend_matches_gather_all_archs(arch):
    """ServeEngine(decode_backend='pallas_paged') serves a mixed
    greedy+stochastic workload with generations identical to the
    gather engine — growth past the prefill cap included."""
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    kw = dict(max_len=16, max_batch=2,
              paged=PagedCacheConfig(page_size=PAGE, max_ctx=32))
    gather = ServeEngine(model, params, **kw)
    kernel = ServeEngine(model, params, decode_backend="pallas_paged", **kw)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9, 3)]
    temps, topks = [0.0, 50.0, 50.0], [None, None, 5]
    a = gather.serve(prompts, 18, temperature=temps, top_k=topks, seed=11)
    b = kernel.serve(prompts, 18, temperature=temps, top_k=topks, seed=11)
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"{arch} request {i}")


def test_engine_kernel_backend_preemption_resume():
    """A tight resident-page budget forces offload mid-serve on the
    kernel backend; generations still match the gather engine."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    kw = dict(max_len=16, max_batch=2,
              paged=PagedCacheConfig(page_size=8, max_ctx=32,
                                     resident_pages=6))
    gather = ServeEngine(model, params, **kw)
    kernel = ServeEngine(model, params, decode_backend="pallas_paged", **kw)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (6, 9, 4)]
    tg, tk = [ServeTelemetry(TrafficModel.from_config(
        get_config("qwen1.5-0.5b"), max_len=4096, page_size=8))
        for _ in range(2)]
    a = gather.serve(prompts, 20, seed=5, telemetry=tg)
    b = kernel.serve(prompts, 20, seed=5, telemetry=tk)
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"request {i}")
    assert tk.page_outs > 0 and tk.page_ins > 0   # preemption happened


def test_engine_rejects_kernel_backend_without_paging():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(ValueError, match="pallas_paged"):
        ServeEngine(model, params, max_len=16, max_batch=2,
                    decode_backend="pallas_paged")
    with pytest.raises(ValueError, match="decode_backend"):
        ServeEngine(model, params, max_len=16, max_batch=2,
                    decode_backend="vulkan")


# ---------------------------------------------------------------------------
# telemetry: kernel path accounts per-page bytes only
# ---------------------------------------------------------------------------
def test_kernel_telemetry_per_page_reads_only():
    """Acceptance: on the kernel path the RTC profile sees true
    per-page reads — zero materialized-view traffic — while the gather
    path pays the phantom whole-view copy every step."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    kw = dict(max_len=16, max_batch=2,
              paged=PagedCacheConfig(page_size=4, max_ctx=32))
    t = TrafficModel.from_config(get_config("qwen1.5-0.5b"), max_len=4096,
                                 page_size=4)
    tg, tk = ServeTelemetry(t), ServeTelemetry(t)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 8)]
    ServeEngine(model, params, **kw).serve(prompts, 8, telemetry=tg)
    ServeEngine(model, params, decode_backend="pallas_paged", **kw) \
        .serve(prompts, 8, telemetry=tk)

    assert tg.decode_mode == "gather" and tk.decode_mode == "pallas_paged"
    # same schedule, so per-step shapes line up
    assert tg.decode_steps == tk.decode_steps
    # kernel path: no phantom traffic, page-granular KV reads
    assert tk.gather_read_bytes_total == 0
    assert tk.gather_write_bytes_total == 0
    assert tg.gather_read_bytes_total > 0
    assert tg.gather_write_bytes_total > 0
    # page-rounding reads at least the row-exact sweep, and the gather
    # path's total (sweep + phantom) strictly dominates the kernel's
    assert tk.kv_read_bytes_total >= tg.kv_read_bytes_total
    wg = tg.workload_profile(step_period_s=0.01)
    wk = tk.workload_profile(step_period_s=0.01)
    assert wg.read_bytes_per_iter > wk.read_bytes_per_iter
    assert wg.write_bytes_per_iter > wk.write_bytes_per_iter
    # per-page reads are exact: reconstruct from the traffic model
    assert t.kv_page_read_bytes(5) == sum(
        (-(-min(5, c) // 4) * 4) * b
        for c, b in zip(t.kv_caps, t.kv_token_bytes))


def test_explicit_decode_mode_is_pinned():
    """A mode passed to the constructor survives engine configuration
    (and bad modes are rejected eagerly)."""
    t = TrafficModel.from_config(get_config("qwen1.5-0.5b"), max_len=64)
    tele = ServeTelemetry(t, decode_mode="contiguous")
    tele.configure_decode(backend="gather", paged=True)
    assert tele.decode_mode == "contiguous"
    auto = ServeTelemetry(t)
    auto.configure_decode(backend="gather", paged=True)
    assert auto.decode_mode == "gather"
    auto.configure_decode(backend="gather", paged=False)
    assert auto.decode_mode == "contiguous"
    with pytest.raises(ValueError, match="decode_mode"):
        ServeTelemetry(t, decode_mode="magic")
