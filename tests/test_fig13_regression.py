"""Regression pin for the Fig. 13 beyond-CNN applications
(benchmarks/fig13_other_apps).

Two layers of assertion per (app, module) cell:

* a tight pin (±0.02) on the CURRENT calibration of full-RTC+ DRAM
  energy savings, so silent drift in the energy/allocator models is
  caught by CI;
* the paper's Section VI-E structure: Eigenfaces benefits from both
  mechanisms (PAAR share growing with capacity); BCPNN's fully-allocated
  4x-per-iteration sweep makes RTT the winner and PAAR nearly useless;
  BFAST's random index walks are not AGU-expressible, so RTT is
  bypassed entirely (exactly zero) and total savings stay ~0.
"""
import pathlib
import sys

import pytest

from repro.core.allocator import allocate_workload
from repro.core.dram import module
from repro.core.rtc import Variant, evaluate, rtt_paar_split

# the app workload definitions live in the benchmark (one source of
# truth); the repo root is not on sys.path under pytest's import mode
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.fig13_other_apps import apps  # noqa: E402

# (app, dram_gb) -> full-RTC+ savings, current calibration
EXPECTED = {
    ("eigenfaces", 2): 0.653,
    ("bcpnn", 2): 0.395,
    ("bfast", 2): 0.017,
    ("eigenfaces", 4): 0.794,
    ("bcpnn", 4): 0.395,
    ("bfast", 4): 0.018,
    ("eigenfaces", 8): 0.879,
    ("bcpnn", 8): 0.395,
    ("bfast", 8): 0.019,
}
CALIBRATION_TOL = 0.02


def _cells():
    rows = {}
    for cap_gb in (2, 4, 8):
        spec = module(cap_gb)
        for w in apps(spec):
            alloc = allocate_workload(spec, {"data": w.footprint_bytes})
            rep = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
            rtt, paar = rtt_paar_split(spec, w, alloc)
            rows[(w.name, cap_gb)] = (rep.dram_savings, rtt, paar)
    return rows


@pytest.fixture(scope="module")
def cells():
    return _cells()


@pytest.mark.parametrize("app,gb", sorted(EXPECTED))
def test_fig13_savings_pinned(cells, app, gb):
    got, _, _ = cells[(app, gb)]
    assert got == pytest.approx(EXPECTED[(app, gb)], abs=CALIBRATION_TOL), (
        f"{app}@{gb}GB full-RTC+ drifted from pinned calibration: "
        f"{got:.3f} vs {EXPECTED[(app, gb)]:.3f}")


def test_fig13_mechanism_split(cells):
    """Section VI-E per-app structure (see module docstring)."""
    for gb in (2, 4, 8):
        rtc, rtt, paar = cells[("eigenfaces", gb)]
        # RTC+ stacks both mechanisms for this re-reading streamer
        assert rtc == pytest.approx(rtt + paar, abs=1e-6)
        _, b_rtt, b_paar = cells[("bcpnn", gb)]
        assert b_rtt > 5 * b_paar        # RTT dominates, PAAR ~useless
        f_rtc, f_rtt, _ = cells[("bfast", gb)]
        assert f_rtt == 0.0              # irregular: RTT bypassed
        assert f_rtc < 0.05              # "the RTC circuitry is bypassed"
    # PAAR share of eigenfaces grows with module capacity
    paars = [cells[("eigenfaces", gb)][2] for gb in (2, 4, 8)]
    assert paars == sorted(paars)
