"""Distribution layer: sharding rules, axis env, dry-run analysis on a
tiny mesh (all on the single CPU device — the 512-device run lives in
``repro.launch.dryrun``)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.axisenv import axis_env, constrain
from repro.dist.sharding import ShardingPolicy, param_specs
from repro.launch.mesh import make_mesh
from repro.models.transformer import TransformerLM


def _specs_for(arch, policy=None, smoke=True):
    cfg = get_config(arch, smoke=smoke)
    model = TransformerLM(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    return shapes, param_specs(shapes, policy or ShardingPolicy())


def test_dense_rules():
    shapes, specs = _specs_for("gemma-2b")
    assert specs["embed"]["tok"] == P("model", None)
    blk = specs["blocks"][0]
    assert blk["attn"]["wq"] == P(None, None, "model")
    assert blk["attn"]["wo"] == P(None, "model", None)
    assert blk["mlp"]["wi"] == P(None, None, "model")
    assert blk["mlp"]["wo"] == P(None, "model", None)
    assert blk["ln1"]["scale"] == P(None, None)


def test_moe_rules_divisibility():
    pol16 = ShardingPolicy(mesh_axis_sizes=(("data", 16), ("model", 16)))
    # dbrx: 16 experts on a 16-way axis -> expert parallel
    _, specs = _specs_for("dbrx-132b", pol16, smoke=False)
    assert specs["blocks"][0]["moe"]["wi"] == P(None, "model", None, None)
    # mixtral: 8 experts x virtual split 2 -> 16 storage experts,
    # also expert parallel
    _, specs = _specs_for("mixtral-8x22b", pol16, smoke=False)
    assert specs["blocks"][0]["moe"]["wi"] == P(None, "model", None, None)
    # non-divisible expert count (no virtual split) -> TP inside experts
    import dataclasses
    from repro.configs import get_config
    from repro.dist.sharding import param_specs as ps
    cfg = dataclasses.replace(get_config("mixtral-8x22b"),
                              moe_virtual_split=1)
    model = TransformerLM(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = ps(shapes, pol16)
    assert specs["blocks"][0]["moe"]["wi"] == P(None, None, None, "model")
    assert specs["blocks"][0]["moe"]["wo"] == P(None, None, "model", None)


def test_fsdp_adds_data_sharding():
    pol = ShardingPolicy(mesh_axis_sizes=(("data", 16), ("model", 16)),
                         fsdp=True)
    _, specs = _specs_for("mixtral-8x22b", pol, smoke=False)
    wi = specs["blocks"][0]["moe"]["wi"]  # [G, E, d, ff]
    assert "data" in jax.tree.leaves(tuple(wi))  # some dim data-sharded
    # small tensors are left alone
    assert specs["blocks"][0]["ln1"]["scale"] == P(None, None)


def test_ssm_rglru_rules():
    _, specs = _specs_for("falcon-mamba-7b")
    blk = specs["blocks"][0]
    assert blk["ssm"]["in_proj"] == P(None, None, "model")
    assert blk["ssm"]["out_proj"] == P(None, "model", None)
    _, specs = _specs_for("recurrentgemma-2b")
    rec = next(b for b in specs["blocks"] if "rec" in b)
    assert rec["rec"]["wx"] == P(None, None, "model")


def test_axis_env_dedup():
    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh:
        with axis_env(batch_axes=("data",), model_axis="model",
                      seq_axis=("data", "model"), mesh=mesh):
            x = jnp.zeros((2, 4, 8))
            # "S" grabs both axes; "M" must dedup to None, not crash
            y = constrain(x, "B", "S", "M")
            assert y.shape == x.shape


def test_constrain_noop_without_env():
    x = jnp.ones((3, 3))
    assert constrain(x, "B", "M") is x


def test_tiny_mesh_cell_analysis():
    """run_cell works end-to-end on a 1x1 mesh (same code path as the
    512-device dry-run)."""
    from repro.launch.dryrun_lib import CellOptions, run_cell
    from repro.launch.shapes import ShapeSpec
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("tiny_train", 64, 4, "train")
    rec = run_cell(cfg, shape, mesh, CellOptions(exact_costs=True))
    assert rec["flops_per_device"] > 0
    assert rec["terms_s"]["compute_s"] > 0
    assert rec["fits_hbm"]
    assert 0 < rec["useful_compute_ratio"] < 10


def test_cost_analysis_scan_undercount_is_real():
    """The motivation for the exact-cost extrapolation: XLA counts a
    while-loop body once regardless of trip count."""
    def make(n):
        def f(ws, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, ws)[0].sum()
        return f

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    fl = []
    for n in (2, 8):
        ws = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        c = jax.jit(make(n)).lower(ws, x).compile()
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        fl.append(float(ca["flops"]))
    assert fl[0] == fl[1]  # undercount confirmed -> extrapolation needed


def test_collective_parser():
    from repro.launch.dryrun_lib import parse_collectives
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[2,8]<=[16]
  %ag = (bf16[64]{0}, bf16[32]{0}) all-gather-start(%y, %z)
  %cp = u8[1024]{0} collective-permute(%w)
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""
    c = parse_collectives(hlo)
    assert c["by_type"]["all-reduce"]["bytes"] == 128 * 256 * 4
    assert c["by_type"]["all-gather"]["bytes"] == 64 * 2 + 32 * 2
    assert c["by_type"]["collective-permute"]["bytes"] == 1024
    assert c["count"] == 3
