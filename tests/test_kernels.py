"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU BlockSpec tiling)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.rate_match.ops import schedule_bits
from repro.kernels.refresh_sim.ops import window_update

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # b, sq, h, kvh, hd, window, softcap, dtype
    (2, 256, 4, 2, 64, None, None, np.float32),
    (1, 128, 4, 1, 64, 64, 50.0, np.float32),
    (2, 256, 8, 8, 32, None, 30.0, np.float32),
    (1, 512, 2, 2, 128, 128, None, np.float32),
    (1, 256, 6, 3, 64, None, None, np.float32),
    (2, 128, 4, 4, 64, 32, None, jnp.bfloat16),
    (1, 256, 4, 2, 256, None, 50.0, np.float32),
]


@pytest.mark.parametrize(
    "b,sq,h,kvh,hd,window,softcap,dtype", ATTN_CASES)
def test_flash_attention_matches_oracle(b, sq, h, kvh, hd, window, softcap,
                                        dtype, rng):
    q = rng.standard_normal((b, sq, h, hd)).astype(np.float32)
    k = rng.standard_normal((b, sq, kvh, hd)).astype(np.float32)
    v = rng.standard_normal((b, sq, kvh, hd)).astype(np.float32)
    q, k, v = (jnp.asarray(x, dtype) for x in (q, k, v))
    ref = attention(q, k, v, causal=True, window=window, softcap=softcap,
                    backend="ref")
    pal = attention(q, k, v, causal=True, window=window, softcap=softcap,
                    backend="pallas")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(pal, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


# Edge cases (PR 5): sequences that do NOT tile the block grid (the
# kernel pads to the grid and slices back, masking padded keys via
# kv_len) and sliding windows smaller than one tile (the band lives
# entirely inside single blocks; the block-level early exit must not
# skip them).
ATTN_EDGE_CASES = [
    # b, sq, h, kvh, hd, q_blk, kv_blk, window, softcap
    (1, 160, 4, 2, 32, 64, 64, None, None),    # sq % q_block != 0
    (2, 200, 4, 4, 16, 128, 128, 16, 30.0),    # pad + window < one tile
    (1, 100, 2, 1, 16, 64, 64, 1, None),       # window=1: self-only band
    (1, 130, 4, 2, 16, 64, 512, None, 50.0),   # kv_block > seq, pad q
    (2, 96, 4, 2, 16, 64, 32, 24, None),       # window < kv tile, pad q
    (1, 33, 2, 2, 8, 32, 32, 40, None),        # window > seq (no-op band)
]


@pytest.mark.parametrize(
    "b,sq,h,kvh,hd,qb,kb,window,softcap", ATTN_EDGE_CASES)
def test_flash_attention_edge_tiling(b, sq, h, kvh, hd, qb, kb, window,
                                     softcap, rng):
    from repro.kernels.flash_attention.kernel import flash_attention
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, kvh, hd)), jnp.float32)
    ref = attention(q, k, v, causal=True, window=window, softcap=softcap,
                    backend="ref")
    pal = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, q_block=qb, kv_block=kb)
    assert pal.shape == ref.shape      # padding sliced back off
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_vs_model_blocked_path(rng):
    """The model's blocked-jnp attention and the Pallas kernel agree."""
    from repro.models.attention import attn_apply, attn_init
    from repro.models.config import ModelConfig
    import jax
    cfg = ModelConfig("t", "dense", 2, 64, 4, 2, 128, 256, head_dim=16,
                      dtype="float32", window_size=128,
                      attn_pattern=("local",))
    params = attn_init(jax.random.key(0), cfg, jnp.float32)
    # compare raw sdpa path: extract q/k/v through the kernel op
    x = rng.standard_normal((2, 256, 64)).astype(np.float32)
    # model path (includes projections + rope) — just ensure it runs on
    # a >2*QBLOCK sequence exercising the blocked branch
    from repro.models import attention as A
    old = A.QBLOCK
    A.QBLOCK = 64
    try:
        pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
        out_blocked = attn_apply(params, cfg, jnp.asarray(x), pos, "local")
        A.QBLOCK = 4096  # force direct path
        out_direct = attn_apply(params, cfg, jnp.asarray(x), pos, "local")
    finally:
        A.QBLOCK = old
    np.testing.assert_allclose(np.asarray(out_blocked),
                               np.asarray(out_direct), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# refresh_sim kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_rows", [8192, 16384, 20000])
@pytest.mark.parametrize("skip", [0, 1])
def test_refresh_window_update_matches_ref(n_rows, skip, rng):
    age = jnp.asarray(rng.integers(0, 2, n_rows), jnp.int32)
    args = dict(acc_start=100, acc_len=700, alloc_lo=50, alloc_hi=5000,
                ref_lo=0, ref_hi=n_rows, skip_accessed=skip)
    a = window_update(age, backend="ref", **args)
    b = window_update(age, backend="pallas", **args)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    for x, y in zip(a[1:], b[1:]):
        assert int(x) == int(y)


# ---------------------------------------------------------------------------
# rate_match kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("na,nr,length", [
    (2, 4, 64), (3, 5, 100), (128, 1024, 2048), (0, 7, 16),
    (1_000_000, 4_194_304, 4096),
])
def test_rate_match_kernel_matches_ref(na, nr, length):
    a = np.asarray(schedule_bits(na, nr, length, backend="ref"))
    b = np.asarray(schedule_bits(na, nr, length, backend="pallas"))
    np.testing.assert_array_equal(a, b)
