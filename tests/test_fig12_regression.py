"""Regression pin for the Fig. 12 density-scaling curve
(benchmarks/fig12_scaling).

fig10/fig11 have been pinned since PR 1/PR 3; this pins the refresh
share of DRAM energy vs chip density.  Two layers of assertion per
density point of the peak-bandwidth streaming setup:

* a tight pin (±0.02) on the CURRENT calibration of the baseline
  refresh share, so silent drift in the energy model is caught by CI;
* the paper's Section VI-D claims: the baseline share grows
  monotonically with density toward ~46-47% at 64 Gb (current
  calibration 0.495, within the ±0.05 paper band), while RTC-enabled
  DRAM nearly eliminates refresh for this CNN-style workload at every
  density.
"""
import dataclasses

import pytest

from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import FIG12_DENSITIES_GBIT, chip
from repro.core.energy import dram_power
from repro.core.rtc import Variant, evaluate
from repro.core.workload import from_cnn

PEAK_BW = 51.2e9   # B/s — matches benchmarks/fig12_scaling.py

# density (Gbit) -> (baseline refresh share, rtc refresh share)
EXPECTED = {
    2: (0.030, 0.0),
    4: (0.059, 0.0),
    8: (0.111, 0.0),
    16: (0.199, 0.0),
    32: (0.331, 0.0),
    64: (0.495, 0.0),
}
CALIBRATION_TOL = 0.02
PAPER_64GB_SHARE = 0.46
PAPER_TOL = 0.05


def _shares(gbit: int):
    spec = chip(gbit, peak_bw_bytes=PEAK_BW)
    base_cnn = from_cnn(CNN_ZOO["alexnet"], fps=60)
    w = dataclasses.replace(
        base_cnn,
        name=f"peakbw@{gbit}Gb",
        read_bytes_per_iter=PEAK_BW * base_cnn.iter_period_s * 0.9,
        write_bytes_per_iter=PEAK_BW * base_cnn.iter_period_s * 0.1,
    )
    baseline = dram_power(spec, w).refresh_fraction
    alloc = allocate_workload(
        spec, {"data": min(w.footprint_bytes, spec.capacity_bytes)})
    rtc = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
    return baseline, rtc.policy.refresh / rtc.policy.total


@pytest.mark.parametrize("gbit", sorted(EXPECTED))
def test_fig12_refresh_share_pinned(gbit):
    base, rtc = _shares(gbit)
    exp_base, exp_rtc = EXPECTED[gbit]
    assert base == pytest.approx(exp_base, abs=CALIBRATION_TOL), (
        f"{gbit}Gb: baseline refresh share drifted from pinned "
        f"calibration: {base:.3f} vs {exp_base:.3f}")
    assert rtc == pytest.approx(exp_rtc, abs=CALIBRATION_TOL), (
        f"{gbit}Gb: RTC refresh share drifted: {rtc:.3f} vs {exp_rtc:.3f}")


def test_fig12_monotonic_growth_and_paper_anchor():
    """Refresh share grows with density; RTC keeps it near zero at every
    density; the 64 Gb baseline lands in the paper's ~46-47% band."""
    shares = {g: _shares(g) for g in FIG12_DENSITIES_GBIT}
    bases = [shares[g][0] for g in FIG12_DENSITIES_GBIT]
    assert bases == sorted(bases)
    assert all(rtc < 0.02 for _, rtc in shares.values())
    assert shares[64][0] == pytest.approx(PAPER_64GB_SHARE, abs=PAPER_TOL)
