"""Trace-driven simulator: affine-equivalence contract + engine pins.

Two layers:

* ``simulate_trace`` on :func:`repro.core.trace.affine_masks` must
  reproduce :func:`repro.core.refresh_sim.simulate` EXACTLY — same
  implicit/explicit/violation counts and energies, for every variant,
  with and without bank rounding.  This is what licenses comparing
  trace-driven numbers against the closed-form model at all.
* a real (smoke) paged serve's trace is deterministic — page accesses
  depend on context lengths and scheduling, never token values — so its
  derived counts are pinned here, end to end through placement and the
  event-level simulator (the fig10_trace benchmark's contract).
"""
import jax
import numpy as np
import pytest

from repro.core.dram import DRAMSpec
from repro.core.placement import (PLACEMENT_POLICIES, build_placement,
                                  fitting_spec)
from repro.core.refresh_sim import simulate, simulate_trace
from repro.core.rtc import Variant
from repro.core.trace import PageAccessTrace, affine_masks, window_masks

SPEC = DRAMSpec(capacity_bytes=16384 * 2048)  # 16k rows — fast

ALL_VARIANTS = (Variant.BASELINE, Variant.MIN_RTC, Variant.MID_RTC,
                Variant.FULL_RTC, Variant.FULL_RTC_PLUS,
                Variant.SMART_REFRESH, Variant.NO_REFRESH)

CASES = {
    "streaming": dict(alloc_lo=0, alloc_rows=4096,
                      rows_accessed_per_window=1024, n_windows=12),
    "misaligned": dict(alloc_lo=100, alloc_rows=3000,
                       rows_accessed_per_window=700, n_windows=8),
    "saturated": dict(alloc_lo=64, alloc_rows=512,
                      rows_accessed_per_window=512, n_windows=6),
    "oversized": dict(alloc_lo=37, alloc_rows=1000,
                      rows_accessed_per_window=2500, n_windows=5),
    "matched": dict(alloc_lo=0, alloc_rows=8000,
                    rows_accessed_per_window=SPEC.n_rows, n_windows=4),
}


def _equiv(variant, kw, bank_rounded):
    a = simulate(SPEC, variant, bank_rounded=bank_rounded, **kw)
    masks = affine_masks(
        SPEC.n_rows, alloc_lo=kw["alloc_lo"], alloc_rows=kw["alloc_rows"],
        rows_accessed_per_window=kw["rows_accessed_per_window"],
        n_windows=kw["n_windows"])
    b = simulate_trace(
        SPEC, variant, masks=masks, alloc_lo=kw["alloc_lo"],
        alloc_rows=kw["alloc_rows"], bank_rounded=bank_rounded,
        matched=kw["rows_accessed_per_window"] >= SPEC.n_rows)
    return a, b


@pytest.mark.parametrize("bank_rounded", [False, True])
@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_affine_equivalence_exact(variant, case, bank_rounded):
    a, b = _equiv(variant, CASES[case], bank_rounded)
    assert (a.implicit_refreshes, a.explicit_refreshes, a.violations) == \
           (b.implicit_refreshes, b.explicit_refreshes, b.violations), \
        (variant, case, bank_rounded)
    assert a.refresh_energy_j == b.refresh_energy_j
    assert a.baseline_refresh_energy_j == b.baseline_refresh_energy_j
    assert a.refresh_savings == b.refresh_savings


def test_min_rtc_matched_needs_explicit_flag():
    """MIN_RTC's all-or-nothing gate keys on the access RATE
    (acc >= n_rows), which a touched-rows bitmap cannot express once
    the allocation is smaller than the module: the 'matched' affine
    case covers only its allocation's rows, so the derived default
    (every module row touched) is False and MIN_RTC keeps refreshing —
    callers replaying affine streams must pass ``matched`` through."""
    kw = CASES["matched"]
    masks = affine_masks(
        SPEC.n_rows, alloc_lo=kw["alloc_lo"], alloc_rows=kw["alloc_rows"],
        rows_accessed_per_window=kw["rows_accessed_per_window"],
        n_windows=kw["n_windows"])
    trace_kw = dict(masks=masks, alloc_lo=kw["alloc_lo"],
                    alloc_rows=kw["alloc_rows"])
    derived = simulate_trace(SPEC, Variant.MIN_RTC, **trace_kw)
    explicit = simulate_trace(SPEC, Variant.MIN_RTC, matched=True,
                              **trace_kw)
    affine = simulate(SPEC, Variant.MIN_RTC, **kw)
    assert explicit.explicit_refreshes == affine.explicit_refreshes == 0
    assert derived.explicit_refreshes == SPEC.n_rows * kw["n_windows"]


def test_irregular_trace_stays_violation_free():
    """Beyond affine reach: a random (hot/cold skewed) bitmap still
    upholds the integrity invariant under FULL_RTC and beats the
    variant's own explicit count under BASELINE."""
    rng = np.random.default_rng(11)
    alloc_lo, alloc_rows, wins = 200, 2048, 10
    masks = np.zeros((wins, SPEC.n_rows), bool)
    hot = rng.choice(alloc_rows, size=300, replace=False)
    for w in range(wins):
        cold = rng.choice(alloc_rows, size=500, replace=False)
        masks[w, alloc_lo + hot] = True
        masks[w, alloc_lo + cold] = True
    full = simulate_trace(SPEC, Variant.FULL_RTC, masks=masks,
                          alloc_lo=alloc_lo, alloc_rows=alloc_rows)
    base = simulate_trace(SPEC, Variant.BASELINE, masks=masks,
                          alloc_lo=alloc_lo, alloc_rows=alloc_rows)
    assert full.violations == base.violations == 0
    assert full.explicit_refreshes < base.explicit_refreshes
    assert full.refresh_savings > 0.9   # tight alloc on a 16k-row module


# ---------------------------------------------------------------------------
# engine integration: the fig10_trace smoke serve, pinned
# ---------------------------------------------------------------------------
PROMPT_LENS = (4, 9, 6, 12)


@pytest.fixture(scope="module")
def served_trace():
    from repro.models.transformer import TransformerLM
    from repro.configs import get_config
    from repro.serve import (PagedCacheConfig, ServeEngine, ServeTelemetry,
                             TrafficModel)

    smoke = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(smoke)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=32, max_batch=2,
                         paged=PagedCacheConfig(page_size=8,
                                                resident_pages=6))
    trace = PageAccessTrace(engine._table.stream_names())
    tele = ServeTelemetry(TrafficModel.from_config(smoke, max_len=32,
                                                   page_size=8),
                          trace=trace)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, smoke.vocab_size, (n,)).astype(np.int32)
               for n in PROMPT_LENS]
    engine.serve(prompts, max_new_tokens=12, seed=7, telemetry=tele)
    geoms = engine._table.stream_geometries()
    pbytes = smoke.param_counts()["total"] * 2   # bf16
    return trace, geoms, pbytes


def test_trace_shape_is_deterministic(served_trace):
    """Scheduling (2 slots, 4 requests, tight page budget) fully
    determines the access stream; pin its shape."""
    trace, geoms, _ = served_trace
    assert trace.stream_names == ("kv:groups0",)
    assert trace.n_steps > len(PROMPT_LENS)   # decode steps + admissions
    # every step touches at least one page of the only stream
    assert all(step.accesses for step in trace.steps)
    seen = trace.pages_touched()
    assert len(seen) == len(geoms)
    assert 0 < seen[0] <= geoms[0].n_pages


def test_placement_policy_ordering_pinned(served_trace):
    """The qualitative fig10_trace story, as an invariant: interleaving
    widens the PAAR allocation, so row-major (and its co-located
    refinement) always saves at least as much under FULL_RTC; every
    policy stays violation-free."""
    trace, geoms, pbytes = served_trace
    spec = fitting_spec(geoms, param_bytes=pbytes)
    savings = {}
    for policy in PLACEMENT_POLICIES:
        pl = build_placement(policy, spec, geoms, param_bytes=pbytes)
        masks = window_masks(trace, pl)
        assert masks.shape == (trace.n_steps, spec.n_rows)
        res = simulate_trace(spec, Variant.FULL_RTC, masks=masks,
                             alloc_lo=pl.alloc_lo,
                             alloc_rows=pl.alloc_rows)
        assert res.violations == 0, policy
        savings[policy] = res.refresh_savings
    assert savings["bank-interleaved"] < savings["row-major"]
    assert savings["slot-colocated"] >= savings["row-major"] - 1e-12
    assert all(0.0 < s <= 1.0 for s in savings.values())
