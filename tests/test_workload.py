"""Workload accounting fixes (PR 9): decode seq_len validation and the
traffic-weighted merge of ``row_utilization``."""
import dataclasses

import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.configs import get_config
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import MODULE_8GB
from repro.core.workload import (WorkloadError, from_cnn, lm_workload,
                                 merge)


# ---------------------------------------------------------------------------
# satellite 1: lm_workload decode must reject an empty context
# ---------------------------------------------------------------------------
def test_decode_zero_seq_len_raises():
    """Regression for the silent ``max(seq_len, 1)`` clamp: a decode
    profile with seq_len=0 used to bill one token of KV sweep and
    footprint for a context the caller said did not exist."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    with pytest.raises(WorkloadError, match=r"seq_len=0"):
        lm_workload(cfg, "decode", 0.02, seq_len=0)
    with pytest.raises(WorkloadError, match=r"seq_len=-3"):
        lm_workload(cfg, "decode", 0.02, seq_len=-3)


def test_decode_error_is_a_value_error():
    """Callers that guarded the old clamp with ``except ValueError``
    keep working: WorkloadError subclasses it."""
    assert issubclass(WorkloadError, ValueError)


def test_decode_minimal_context_accounts_one_token():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    w1 = lm_workload(cfg, "decode", 0.02, seq_len=1)
    w2 = lm_workload(cfg, "decode", 0.02, seq_len=2)
    # KV sweep and footprint grow with the context; the per-step append
    # (writes) does not
    assert w2.read_bytes_per_iter > w1.read_bytes_per_iter
    assert w2.footprint_bytes > w1.footprint_bytes
    assert w2.write_bytes_per_iter == w1.write_bytes_per_iter


def test_train_ignores_seq_len():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    w = lm_workload(cfg, "train", 0.02, seq_len=0)
    assert w.footprint_bytes > 0


# ---------------------------------------------------------------------------
# satellite 2: merge() row_utilization is the traffic-weighted harmonic
# mean — the merged profile's ACT rate equals the sum of the parts'
# ---------------------------------------------------------------------------
def _cnn(name, fps, ru):
    return from_cnn(CNN_ZOO[name], fps=fps, row_utilization=ru)


def test_merge_equal_utilization_is_identity():
    """All fig11 mixes run the 0.5 default: the weighted mean of equal
    values is that value, so the pinned calibration is untouched."""
    ws = [_cnn("alexnet", 60, 0.5), _cnn("googlenet", 60, 0.5)]
    assert merge("mix", *ws).row_utilization == pytest.approx(0.5)


def test_merge_mixed_utilization_sums_act_rates():
    ws = [_cnn("alexnet", 60, 0.25), _cnn("lenet", 30, 1.0)]
    merged = merge("mix", *ws)
    want = sum(w.row_activations_per_s(MODULE_8GB) for w in ws)
    got = merged.row_activations_per_s(MODULE_8GB)
    assert got == pytest.approx(want, rel=1e-9)
    # the old min() billed every byte — lenet's included — at
    # alexnet's 0.25 rows-per-byte efficiency, overstating the ACT rate
    old_min = dataclasses.replace(merged, row_utilization=0.25)
    assert old_min.row_activations_per_s(MODULE_8GB) > got


@given(
    ru_a=st.floats(0.05, 1.0),
    ru_b=st.floats(0.05, 1.0),
    fps_a=st.sampled_from([15, 30, 60]),
    fps_b=st.sampled_from([15, 30, 60]),
)
@settings(max_examples=25, deadline=None)
def test_merge_act_sum_invariant_property(ru_a, ru_b, fps_a, fps_b):
    """The invariant that motivates the harmonic mean, across periods
    and utilizations: each stream opens rows at its own efficiency, so
    aggregate ACT/s is conserved under merge."""
    ws = [_cnn("alexnet", fps_a, ru_a), _cnn("googlenet", fps_b, ru_b)]
    merged = merge("mix", *ws)
    want = sum(w.row_activations_per_s(MODULE_8GB) for w in ws)
    assert merged.row_activations_per_s(MODULE_8GB) == \
        pytest.approx(want, rel=1e-9)
    lo = min(ru_a, ru_b)
    hi = max(ru_a, ru_b)
    assert lo - 1e-12 <= merged.row_utilization <= hi + 1e-12


def test_merge_empty_raises():
    with pytest.raises(ValueError):
        merge("nothing")
