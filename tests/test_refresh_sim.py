"""Event-level simulator: integrity invariant + analytic cross-check."""
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.dram import DRAMSpec
from repro.core.refresh_sim import simulate
from repro.core.rtc import Variant

SPEC = DRAMSpec(capacity_bytes=16384 * 2048)  # 16k rows — fast


@pytest.mark.parametrize("variant", [
    Variant.BASELINE, Variant.FULL_RTC, Variant.MID_RTC,
    Variant.SMART_REFRESH,
])
def test_no_retention_violations(variant):
    r = simulate(SPEC, variant, alloc_rows=4096,
                 rows_accessed_per_window=1024, n_windows=12,
                 bank_rounded=(variant is Variant.MID_RTC))
    assert r.violations == 0, variant


def test_no_refresh_oracle_violates():
    """Sanity: without refresh, unaccessed allocated rows decay —
    the invariant detector actually detects."""
    r = simulate(SPEC, Variant.NO_REFRESH, alloc_rows=4096,
                 rows_accessed_per_window=1024, n_windows=4)
    assert r.violations > 0


def test_fullrtc_matches_analytic_closed_form():
    """Simulated refresh savings == analytic remaining fraction
    (bound_frac * (1 - f_c_bound)) for the streaming pattern."""
    alloc, na, nrows = 4096, 1024, SPEC.n_rows
    r = simulate(SPEC, Variant.FULL_RTC, alloc_rows=alloc,
                 rows_accessed_per_window=na, n_windows=16)
    expected = 1.0 - (alloc - na) / nrows
    assert abs(r.refresh_savings - expected) < 1e-6


def test_baseline_refreshes_everything():
    r = simulate(SPEC, Variant.BASELINE, alloc_rows=1024,
                 rows_accessed_per_window=256, n_windows=8)
    assert r.explicit_refreshes == SPEC.n_rows * 8
    assert r.refresh_savings == 0.0


@given(
    alloc=st.integers(256, 8192),
    na=st.integers(1, 8192),
    windows=st.integers(2, 8),
)
@settings(max_examples=25, deadline=None)
def test_fullrtc_integrity_property(alloc, na, windows):
    na = min(na, alloc)
    r = simulate(SPEC, Variant.FULL_RTC, alloc_rows=alloc,
                 rows_accessed_per_window=na, n_windows=windows)
    assert r.violations == 0
    assert 0.0 <= r.refresh_savings <= 1.0
    # savings at least the PAAR floor (unallocated rows never refresh)
    paar_floor = 1.0 - alloc / SPEC.n_rows
    assert r.refresh_savings >= paar_floor - 1e-9


@pytest.mark.parametrize("variant", [Variant.MID_RTC, Variant.FULL_RTC])
def test_bank_rounding_only_widens_refresh_predicate(variant):
    """Regression: bank rounding must widen only the explicit-refresh
    bound, NOT the simulated access stream — the workload still touches
    exactly its allocation, so implicit (access-coalesced) refreshes are
    identical with rounding on or off for the same stream, and the
    widened REF span can only add explicit refreshes."""
    kw = dict(alloc_rows=3000, rows_accessed_per_window=700,
              n_windows=8, alloc_lo=100)   # deliberately bank-misaligned
    assert kw["alloc_lo"] % SPEC.rows_per_bank != 0
    assert (kw["alloc_lo"] + kw["alloc_rows"]) % SPEC.rows_per_bank != 0
    a = simulate(SPEC, variant, **kw, bank_rounded=False)
    b = simulate(SPEC, variant, **kw, bank_rounded=True)
    assert a.implicit_refreshes == b.implicit_refreshes
    assert b.explicit_refreshes >= a.explicit_refreshes
    assert a.violations == 0 and b.violations == 0


def test_pallas_backend_matches_ref():
    kw = dict(alloc_rows=5000, rows_accessed_per_window=1500, n_windows=6)
    a = simulate(SPEC, Variant.FULL_RTC, backend="ref", **kw)
    b = simulate(SPEC, Variant.FULL_RTC, backend="pallas", **kw)
    assert (a.explicit_refreshes, a.implicit_refreshes, a.violations) == \
           (b.explicit_refreshes, b.implicit_refreshes, b.violations)


@given(
    alloc=st.integers(1, 4096),
    excess=st.integers(0, 8192),
    windows=st.integers(1, 6),
    lo=st.integers(0, 2048),
)
@settings(max_examples=40, deadline=None)
def test_oversized_access_saturates_allocation(alloc, excess, windows, lo):
    """PR 9 audit pin: rows_accessed_per_window > alloc_rows must
    SATURATE the allocation (every allocated row accessed every
    window), never alias back through ``% span`` into a partial sweep.
    Any oversized rate is therefore exactly equivalent to
    rows_accessed_per_window == alloc_rows."""
    kw = dict(alloc_lo=lo, alloc_rows=alloc, n_windows=windows)
    over = simulate(SPEC, Variant.FULL_RTC,
                    rows_accessed_per_window=alloc + excess, **kw)
    exact = simulate(SPEC, Variant.FULL_RTC,
                     rows_accessed_per_window=alloc, **kw)
    assert over.implicit_refreshes == alloc * windows
    assert (over.implicit_refreshes, over.explicit_refreshes,
            over.violations) == (exact.implicit_refreshes,
                                 exact.explicit_refreshes, exact.violations)


def test_masked_pallas_matches_ref():
    """The trace-path kernel (window_update_masked) agrees with its
    reference across an unaligned size that forces block padding."""
    import numpy as np

    from repro.kernels.refresh_sim.ops import window_update_masked

    rng = np.random.default_rng(3)
    n = 9000   # not a multiple of BLOCK_ROWS -> exercises padding
    age = rng.integers(0, 2, n).astype(np.int32)
    touched = rng.integers(0, 2, n).astype(np.int32)
    kw = dict(alloc_lo=100, alloc_hi=7000, ref_lo=100, ref_hi=7000,
              skip_accessed=1)
    a = window_update_masked(age, touched, backend="ref", **kw)
    b = window_update_masked(age, touched, backend="pallas", **kw)
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert tuple(int(x) for x in a[1:]) == tuple(int(x) for x in b[1:])
