"""Hypothesis compatibility shim.

Test modules import ``given`` / ``settings`` / ``strategies`` from
here instead of from ``hypothesis`` directly.  When the real package
is installed (the ``[test]`` extra), it is used unchanged; otherwise a
minimal fallback runs each property as a **fixed deterministic example
sweep**: boundary values first, then draws from a seed-0 PRNG, capped
at ``min(max_examples, 50)`` examples.  No shrinking, no database —
just enough to keep the properties exercised on hermetic CPU runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies
except ImportError:
    import random
    import types

    _MAX_EXAMPLES_CAP = 50

    class _Strategy:
        """A draw function plus explicit boundary examples."""

        def __init__(self, draw, edges=()):
            self.draw = draw
            self.edges = tuple(edges)

        def example(self, rng, i):
            if i < len(self.edges):
                return self.edges[i]
            return self.draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         edges=(min_value, max_value))

    def _floats(min_value, max_value, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         edges=(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5,
                         edges=(False, True))

    def _just(value):
        return _Strategy(lambda rng: value, edges=(value,))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                         edges=(seq[0], seq[-1]))

    def _lists(elements, min_size=0, max_size=10, **_):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _builds(target, **kwargs):
        def draw(rng):
            return target(**{k: s.draw(rng) for k, s in kwargs.items()})
        return _Strategy(draw)

    strategies = types.SimpleNamespace(
        integers=_integers, floats=_floats, booleans=_booleans,
        just=_just, sampled_from=_sampled_from, lists=_lists,
        builds=_builds,
    )

    def settings(max_examples=None, **_):
        """Records max_examples on the function; other knobs ignored."""
        def deco(fn):
            if max_examples is not None:
                fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples", 10),
                    _MAX_EXAMPLES_CAP)

            # Deliberately NOT functools.wraps: the wrapper must expose
            # a zero-arg signature so pytest doesn't treat the property
            # arguments as fixtures.
            def wrapper():
                rng = random.Random(0)
                for i in range(n):
                    args = [s.example(rng, i) for s in arg_strategies]
                    kwargs = {k: s.example(rng, i)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

__all__ = ["given", "settings", "strategies"]
