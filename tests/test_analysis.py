"""Static analyzer: golden findings on hand-built jaxprs + engine audits.

Three layers, cheapest first:

* **Walker goldens** — tiny ``jax.make_jaxpr`` programs exercising one
  billing rule each (structural ops free, compute reads billed, gather
  materializes the view, scatter/dus stays in-place, scan multiplies,
  missing pallas cost handler reported).
* **Pass goldens** — hand-built :class:`Artifact`/:class:`AuditUnit`
  objects that force exactly one finding per registered pass (traffic
  drift, GSPMD gather around a pallas call, unsharded pool page dim,
  donation / large-constant / f64 hygiene), pinning the finding *keys*
  the baseline machinery gates on.
* **Engine cross-checks** — real engines (abstract params, trace only:
  nothing executes) across archs x decode backends must derive byte
  counts equal to ``TrafficModel.static_decode_classes`` class for
  class, and produce zero error findings on a solo topology.

The 2-device GSPMD-gather detection lives in
``test_serve_multidevice.py`` (it needs a forced device count before
jax initializes, hence a subprocess).
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec

from repro.analysis import decode_traffic_report, unit_from_engine
from repro.analysis.artifacts import Artifact, AuditUnit
from repro.analysis.costs import (KernelCost, lookup_pallas_cost,
                                  register_pallas_cost, uniform_cost)
from repro.analysis.jaxpr_walk import (PallasSite, Taint, TRAFFIC_CLASSES,
                                       WalkResult, walk_jaxpr)
from repro.analysis.lints import hygiene_pass, sharding_pass
from repro.analysis.registry import (BASELINE_SCHEMA, Finding,
                                     baseline_payload, diff_baseline,
                                     load_baseline, registered_passes,
                                     run_passes)
from repro.analysis.traffic import GATED_CLASSES, traffic_pass
from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.serve import PagedCacheConfig, ServeEngine, TrafficModel

BASELINE = (pathlib.Path(__file__).parent.parent
            / "src/repro/analysis/baseline.json")
GSPMD_KEY = ("sharding:gspmd-gather-around-pallas-call:"
             "qwen1.5-0.5b/pallas_paged/mesh2:decode:kernels/paged_attention")


def _kv(src=0, **kw):
    return Taint("kv", resident=True, inplace=True, src=src, **kw)


def _bytes(x):
    return int(np.prod(x.shape)) * x.dtype.itemsize


# --------------------------------------------------------------- walker rules
def test_structural_ops_are_free_and_keep_inplace():
    closed = jax.make_jaxpr(lambda k: k.T.reshape(4, 4))(
        jnp.ones((2, 8), jnp.float32))
    res = walk_jaxpr(closed, [_kv()])
    assert all(v == 0 for v in res.buckets.values())
    t = res.outvar_taints[0]
    assert t is not None and t.inplace and t.resident and t.cls == "kv"


def test_compute_read_bills_resident_operand_once():
    k = jnp.ones((2, 8), jnp.float32)
    closed = jax.make_jaxpr(lambda k: (k * 2.0).sum())(k)
    res = walk_jaxpr(closed, [_kv()])
    assert res.buckets["kv_sweep_read"] == _bytes(k)
    # the product is a fresh intermediate: summing it costs nothing
    assert res.outvar_taints[0] is None


def test_dynamic_update_slice_bills_update_bytes_in_place():
    cache = jnp.zeros((8, 4), jnp.float32)
    upd = jnp.ones((1, 4), jnp.float32)
    closed = jax.make_jaxpr(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0)))(
            cache, upd, 3)
    res = walk_jaxpr(closed, [_kv(), None, None])
    assert res.buckets["kv_append_write"] == _bytes(upd)
    assert res.buckets["kv_sweep_read"] == 0      # no full-cache re-read
    t = res.outvar_taints[0]
    assert t is not None and t.inplace            # same buffer flows out


def test_pool_gather_materializes_resident_view():
    pool = jnp.zeros((8, 4, 2), jnp.float32)      # 8 pages
    idx = jnp.array([0, 3, 1])

    def f(pool, idx):
        view = pool[idx]                          # lax.gather
        return (view * 2.0).sum()                 # sweeping the view

    closed = jax.make_jaxpr(f)(pool, idx)
    res = walk_jaxpr(closed, [Taint("kv_pool", src=0), None])
    view_bytes = 3 * 4 * 2 * 4
    assert res.buckets["gather_view_read"] == view_bytes
    assert res.buckets["gather_view_write"] == view_bytes
    assert res.buckets["kv_sweep_read"] == view_bytes


def test_scan_multiplies_body_bytes_by_trip_count():
    w = jnp.ones((4, 4), jnp.float32)
    xs = jnp.zeros((5,), jnp.float32)
    closed = jax.make_jaxpr(
        lambda w, xs: jax.lax.scan(
            lambda c, x: (c + (w * x).sum(), None), 0.0, xs))(w, xs)
    res = walk_jaxpr(closed, [Taint("param", src=0), None])
    assert res.buckets["param_read"] == _bytes(w) * 5


def test_unregistered_pallas_call_is_reported_not_guessed():
    import jax.experimental.pallas as pl

    def _copy(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def f(x):
        return pl.pallas_call(
            _copy, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)

    closed = jax.make_jaxpr(f)(jnp.ones((4, 4), jnp.float32))
    res = walk_jaxpr(closed, [_kv()])
    assert any(p.startswith("missing-cost-handler") for p in res.problems)
    (site,) = res.pallas_sites
    assert site.operand_taints[0].cls == "kv"
    assert all(v == 0 for v in res.buckets.values())   # never guesses


# ------------------------------------------------------------- cost handlers
def test_every_repo_kernel_registers_a_cost_handler():
    import repro.analysis.traffic  # noqa: F401  (imports the ops modules)
    for kernel in ("flash_attention", "paged_attention", "rate_match",
                   "refresh_sim"):
        assert lookup_pallas_cost(
            f"_kernel at /x/src/repro/kernels/{kernel}/kernel.py:1"
        ) is not None, kernel


def test_register_pallas_cost_rejects_conflicting_handler():
    register_pallas_cost("tests/nonexistent-kernel/", uniform_cost)
    register_pallas_cost("tests/nonexistent-kernel/", uniform_cost)  # idempotent
    with pytest.raises(ValueError, match="already registered"):
        register_pallas_cost("tests/nonexistent-kernel/",
                             lambda eqn: KernelCost((), ()))


# ------------------------------------------------------- pass golden findings
def _unit(artifact, mode="contiguous", axis_sizes=None, data_axes=(),
          page_size=0, live=2, ctx=32):
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    return AuditUnit(
        label=f"hand/{mode}/solo", cfg_name=cfg.name, mode=mode,
        traffic=TrafficModel.from_config(cfg, ctx, page_size=page_size),
        live=live, ctx=ctx, axis_sizes=dict(axis_sizes or {}),
        data_axes=tuple(data_axes), artifacts=[artifact])


def _artifact(closed, seeds, *, specs=None, donated=None, expect=None,
              consts=(), out_names=None):
    n = len(seeds)
    return Artifact(
        name="decode", closed_jaxpr=closed, seeds=tuple(seeds),
        invar_labels=tuple(f"arg{i}" for i in range(n)),
        arg_specs=tuple(specs or [None] * n),
        donated=tuple(donated or [False] * n),
        expect_donated=tuple(expect or [False] * n),
        out_leaf_names=tuple(out_names
                             or [""] * len(closed.jaxpr.outvars)),
        consts=tuple(consts))


def test_traffic_pass_flags_drift_per_class():
    # a decode step that moves zero cache bytes, against a model that
    # expects a full KV sweep: every non-zero expected class must drift
    closed = jax.make_jaxpr(lambda x: x + 1.0)(jnp.ones((2, 2), jnp.float32))
    unit = _unit(_artifact(closed, [None]))
    findings = traffic_pass(unit)
    codes = {f.code for f in findings}
    assert codes == {"traffic-drift"}
    drifted = {f.subject.rsplit(":", 1)[-1] for f in findings}
    expected = unit.traffic.static_decode_classes([32, 32], "contiguous")
    assert drifted == {k for k in GATED_CLASSES if expected[k] != 0}
    assert "kv_sweep_read" in drifted
    key = next(iter(findings)).key
    assert key.startswith("traffic:traffic-drift:hand/contiguous/solo:decode")


def test_sharding_pass_flags_gspmd_gather_around_pallas_call():
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)],
                    specs=[PartitionSpec("data", None, None, None)])
    # inject the walk: one pallas site consuming the sharded pool leaf
    art._walk = WalkResult(
        buckets={c: 0 for c in TRAFFIC_CLASSES},
        pallas_sites=[PallasSite(
            name_and_src="_kernel at /x/src/repro/kernels/paged_attention/"
                         "kernel.py:51",
            multiplier=1,
            operand_taints=(Taint("kv_pool", src=0),),
            operand_shapes=((8, 8, 2, 4),))],
        problems=[], outvar_taints=(None,))
    unit = _unit(art, mode="pallas_paged", axis_sizes={"data": 2, "model": 1},
                 page_size=8)
    findings = sharding_pass(unit)
    gather = [f for f in findings
              if f.code == "gspmd-gather-around-pallas-call"]
    assert len(gather) == 1
    assert gather[0].subject.endswith(":decode:kernels/paged_attention")
    assert "arg0" in gather[0].detail


def test_sharding_pass_flags_unsharded_pool_page_dim():
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)])   # spec: replicated
    unit = _unit(art, mode="pallas_paged", axis_sizes={"data": 2},
                 data_axes=("data",), page_size=8)
    codes = {f.code for f in sharding_pass(unit)}
    assert "pool-page-dim-unsharded" in codes


def test_sharding_pass_silent_on_single_device():
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)])
    assert sharding_pass(_unit(art, axis_sizes={"data": 1})) == []


def test_hygiene_pass_flags_donation_constants_and_f64():
    with enable_x64():
        closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(4, jnp.float64))
    art = _artifact(closed, [_kv()], expect=[True], donated=[False],
                    consts=(np.zeros(1 << 19, np.float32),))   # 2 MiB
    codes = {f.code for f in hygiene_pass(_unit(art))}
    assert codes == {"undonated-cache-buffer", "large-captured-constant",
                     "f64-promotion"}


def test_hygiene_pass_clean_artifact_is_silent():
    closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(4, jnp.float32))
    art = _artifact(closed, [_kv()], expect=[True], donated=[True])
    assert hygiene_pass(_unit(art)) == []


# --------------------------------------------------------- registry/baseline
def test_all_three_passes_are_registered():
    assert set(registered_passes()) >= {"traffic", "sharding", "hygiene"}
    with pytest.raises(ValueError, match="unknown analysis pass"):
        run_passes([], only=["nonesuch"])


def test_diff_baseline_gates_new_and_stale_not_info():
    base = {"sharding:gspmd:x": "known"}
    known = Finding("sharding", "gspmd", "x", "d")
    new = Finding("traffic", "traffic-drift", "y", "d")
    info = Finding("hygiene", "note", "z", "d", severity="info")
    got_new, fixed = diff_baseline([known, new, info], base)
    assert [f.key for f in got_new] == [new.key] and fixed == []
    # baselined finding fixed -> its entry is stale and must be deleted
    got_new, fixed = diff_baseline([info], base)
    assert got_new == [] and fixed == ["sharding:gspmd:x"]
    # info findings never enter a regenerated baseline
    assert baseline_payload([info])["findings"] == []


def test_checked_in_baseline_has_only_the_known_gspmd_gather():
    data = json.loads(BASELINE.read_text())
    assert data["schema"] == BASELINE_SCHEMA
    assert [e["key"] for e in data["findings"]] == [GSPMD_KEY]
    assert load_baseline(BASELINE)[GSPMD_KEY]      # note explains the gap


# ------------------------------------------------- engine-level cross-checks
CROSS_ARCHS = ("qwen1.5-0.5b", "gemma2-9b", "recurrentgemma-2b")


def _audit_unit(arch, mode):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    kw = dict(max_len=32, max_batch=2)
    if mode != "contiguous":
        kw.update(paged=PagedCacheConfig(page_size=8), decode_backend=mode)
    return unit_from_engine(ServeEngine(model, params, **kw), arch)


@pytest.mark.parametrize("mode", ("contiguous", "gather", "pallas_paged"))
@pytest.mark.parametrize("arch", CROSS_ARCHS)
def test_static_audit_matches_telemetry_exactly(arch, mode):
    unit = _audit_unit(arch, mode)
    rep = decode_traffic_report(unit)
    assert rep["problems"] == []
    for k in GATED_CLASSES:
        assert rep["derived"].get(k, 0) == rep["expected"][k], (
            f"{arch}/{mode}: {k} derived {rep['derived'].get(k, 0)} "
            f"!= telemetry {rep['expected'][k]}")
    # solo topology: no pass may produce an error finding
    errors = [f for f in run_passes([unit]) if f.severity == "error"]
    assert errors == [], [f.key for f in errors]
