"""Static analyzer: golden findings on hand-built jaxprs + engine audits.

Three layers, cheapest first:

* **Walker goldens** — tiny ``jax.make_jaxpr`` programs exercising one
  billing rule each (structural ops free, compute reads billed, gather
  materializes the view, scatter/dus stays in-place, scan multiplies,
  missing pallas cost handler reported).
* **Pass goldens** — hand-built :class:`Artifact`/:class:`AuditUnit`
  objects that force exactly one finding per registered pass (traffic
  drift, GSPMD gather around a pallas call, unsharded pool page dim,
  donation / large-constant / f64 hygiene), pinning the finding *keys*
  the baseline machinery gates on.
* **Engine cross-checks** — real engines (abstract params, trace only:
  nothing executes) across archs x decode backends must derive byte
  counts equal to ``TrafficModel.static_decode_classes`` class for
  class, and produce zero error findings on a solo topology.
* **HLO collective goldens** (PR 7) — hand-written partitioned-HLO
  lines, one per collective kind plus the iota/explicit/empty
  replica-group forms, async start/done pairs and layout-paren
  operands, pinning the parser's exact per-device wire-byte arithmetic
  and the tensor-family classification the locality lint gates on.
* **Partition gates** (PR 7) — mesh-scoped baseline accounting
  (``@mesh=N`` keys), the per-device bill splitter, and the invariance
  gate on synthetic units; the real 2-vs-8-vs-64 cross-check lowers
  engines in a subprocess (forced device count) under ``slow_serve``.

The 2-device GSPMD-gather detection lives in
``test_serve_multidevice.py`` (it needs a forced device count before
jax initializes, hence a subprocess).
"""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec

from repro.analysis import decode_traffic_report, unit_from_engine
from repro.analysis.artifacts import (Artifact, AuditUnit,
                                      sharded_leaf_factors)
from repro.analysis.costs import (KernelCost, lookup_pallas_cost,
                                  register_pallas_cost, uniform_cost)
from repro.analysis.hlo_walk import (classify_collective, ledger_rows,
                                     parse_collectives)
from repro.analysis.jaxpr_walk import (PallasSite, Taint, TRAFFIC_CLASSES,
                                       WalkResult, walk_jaxpr)
from repro.analysis.lints import hygiene_pass, sharding_pass
from repro.analysis.partition import PartitionUnit, invariance_findings
from repro.analysis.registry import (BASELINE_SCHEMA, Finding,
                                     baseline_payload, diff_baseline,
                                     key_in_scope, key_mesh_size,
                                     load_baseline, registered_passes,
                                     run_passes)
from repro.analysis.traffic import (GATED_CLASSES, split_per_device,
                                    traffic_pass)
from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.serve import PagedCacheConfig, ServeEngine, TrafficModel

BASELINE = (pathlib.Path(__file__).parent.parent
            / "src/repro/analysis/baseline.json")


def _kv(src=0, **kw):
    return Taint("kv", resident=True, inplace=True, src=src, **kw)


def _bytes(x):
    return int(np.prod(x.shape)) * x.dtype.itemsize


# --------------------------------------------------------------- walker rules
def test_structural_ops_are_free_and_keep_inplace():
    closed = jax.make_jaxpr(lambda k: k.T.reshape(4, 4))(
        jnp.ones((2, 8), jnp.float32))
    res = walk_jaxpr(closed, [_kv()])
    assert all(v == 0 for v in res.buckets.values())
    t = res.outvar_taints[0]
    assert t is not None and t.inplace and t.resident and t.cls == "kv"


def test_compute_read_bills_resident_operand_once():
    k = jnp.ones((2, 8), jnp.float32)
    closed = jax.make_jaxpr(lambda k: (k * 2.0).sum())(k)
    res = walk_jaxpr(closed, [_kv()])
    assert res.buckets["kv_sweep_read"] == _bytes(k)
    # the product is a fresh intermediate: summing it costs nothing
    assert res.outvar_taints[0] is None


def test_dynamic_update_slice_bills_update_bytes_in_place():
    cache = jnp.zeros((8, 4), jnp.float32)
    upd = jnp.ones((1, 4), jnp.float32)
    closed = jax.make_jaxpr(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0)))(
            cache, upd, 3)
    res = walk_jaxpr(closed, [_kv(), None, None])
    assert res.buckets["kv_append_write"] == _bytes(upd)
    assert res.buckets["kv_sweep_read"] == 0      # no full-cache re-read
    t = res.outvar_taints[0]
    assert t is not None and t.inplace            # same buffer flows out


def test_pool_gather_materializes_resident_view():
    pool = jnp.zeros((8, 4, 2), jnp.float32)      # 8 pages
    idx = jnp.array([0, 3, 1])

    def f(pool, idx):
        view = pool[idx]                          # lax.gather
        return (view * 2.0).sum()                 # sweeping the view

    closed = jax.make_jaxpr(f)(pool, idx)
    res = walk_jaxpr(closed, [Taint("kv_pool", src=0), None])
    view_bytes = 3 * 4 * 2 * 4
    assert res.buckets["gather_view_read"] == view_bytes
    assert res.buckets["gather_view_write"] == view_bytes
    assert res.buckets["kv_sweep_read"] == view_bytes


def test_walker_shard_map_bills_per_shard_times_shard_count():
    # device-local decode shape: the body gathers from its LOCAL pool
    # extent; per-shard bytes x the shard count (mesh axes not in
    # `auto`) is the exact global bill for evenly split pool operands
    from jax.experimental.shard_map import shard_map
    from jax.sharding import AbstractMesh

    pool = jnp.zeros((8, 4, 2), jnp.float32)      # 4 pages per shard
    idx = jnp.array([0, 3, 1])

    def f(pool, idx):
        view = pool[idx]
        return (view * 2.0).sum()

    smap = shard_map(f, mesh=AbstractMesh((("data", 2), ("model", 1))),
                     in_specs=(PartitionSpec("data"), PartitionSpec()),
                     out_specs=PartitionSpec(), check_rep=False)
    closed = jax.make_jaxpr(smap)(pool, idx)
    assert closed.jaxpr.eqns[0].primitive.name == "shard_map"
    res = walk_jaxpr(closed, [Taint("kv_pool", src=0), None])
    per_shard = 3 * 4 * 2 * 4        # the gathered view of a local pool
    assert res.buckets["gather_view_read"] == 2 * per_shard
    assert res.buckets["gather_view_write"] == 2 * per_shard
    assert res.buckets["kv_sweep_read"] == 2 * per_shard


def test_scan_multiplies_body_bytes_by_trip_count():
    w = jnp.ones((4, 4), jnp.float32)
    xs = jnp.zeros((5,), jnp.float32)
    closed = jax.make_jaxpr(
        lambda w, xs: jax.lax.scan(
            lambda c, x: (c + (w * x).sum(), None), 0.0, xs))(w, xs)
    res = walk_jaxpr(closed, [Taint("param", src=0), None])
    assert res.buckets["param_read"] == _bytes(w) * 5


def test_unregistered_pallas_call_is_reported_not_guessed():
    import jax.experimental.pallas as pl

    def _copy(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def f(x):
        return pl.pallas_call(
            _copy, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)

    closed = jax.make_jaxpr(f)(jnp.ones((4, 4), jnp.float32))
    res = walk_jaxpr(closed, [_kv()])
    assert any(p.startswith("missing-cost-handler") for p in res.problems)
    (site,) = res.pallas_sites
    assert site.operand_taints[0].cls == "kv"
    assert all(v == 0 for v in res.buckets.values())   # never guesses


# ------------------------------------------------------------- cost handlers
def test_every_repo_kernel_registers_a_cost_handler():
    import repro.analysis.traffic  # noqa: F401  (imports the ops modules)
    for kernel in ("flash_attention", "paged_attention", "rate_match",
                   "refresh_sim"):
        assert lookup_pallas_cost(
            f"_kernel at /x/src/repro/kernels/{kernel}/kernel.py:1"
        ) is not None, kernel


def test_register_pallas_cost_rejects_conflicting_handler():
    register_pallas_cost("tests/nonexistent-kernel/", uniform_cost)
    register_pallas_cost("tests/nonexistent-kernel/", uniform_cost)  # idempotent
    with pytest.raises(ValueError, match="already registered"):
        register_pallas_cost("tests/nonexistent-kernel/",
                             lambda eqn: KernelCost((), ()))


# ------------------------------------------------------- pass golden findings
def _unit(artifact, mode="contiguous", axis_sizes=None, data_axes=(),
          page_size=0, live=2, ctx=32):
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    return AuditUnit(
        label=f"hand/{mode}/solo", cfg_name=cfg.name, mode=mode,
        traffic=TrafficModel.from_config(cfg, ctx, page_size=page_size),
        live=live, ctx=ctx, axis_sizes=dict(axis_sizes or {}),
        data_axes=tuple(data_axes), artifacts=[artifact])


def _artifact(closed, seeds, *, specs=None, donated=None, expect=None,
              consts=(), out_names=None):
    n = len(seeds)
    return Artifact(
        name="decode", closed_jaxpr=closed, seeds=tuple(seeds),
        invar_labels=tuple(f"arg{i}" for i in range(n)),
        arg_specs=tuple(specs or [None] * n),
        donated=tuple(donated or [False] * n),
        expect_donated=tuple(expect or [False] * n),
        out_leaf_names=tuple(out_names
                             or [""] * len(closed.jaxpr.outvars)),
        consts=tuple(consts))


def test_traffic_pass_flags_drift_per_class():
    # a decode step that moves zero cache bytes, against a model that
    # expects a full KV sweep: every non-zero expected class must drift
    closed = jax.make_jaxpr(lambda x: x + 1.0)(jnp.ones((2, 2), jnp.float32))
    unit = _unit(_artifact(closed, [None]))
    findings = traffic_pass(unit)
    codes = {f.code for f in findings}
    assert codes == {"traffic-drift"}
    drifted = {f.subject.rsplit(":", 1)[-1] for f in findings}
    expected = unit.traffic.static_decode_classes([32, 32], "contiguous")
    assert drifted == {k for k in GATED_CLASSES if expected[k] != 0}
    assert "kv_sweep_read" in drifted
    key = next(iter(findings)).key
    assert key.startswith("traffic:traffic-drift:hand/contiguous/solo:decode")


def test_sharding_pass_flags_gspmd_gather_around_pallas_call():
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)],
                    specs=[PartitionSpec("data", None, None, None)])
    # inject the walk: one pallas site consuming the sharded pool leaf
    art._walk = WalkResult(
        buckets={c: 0 for c in TRAFFIC_CLASSES},
        pallas_sites=[PallasSite(
            name_and_src="_kernel at /x/src/repro/kernels/paged_attention/"
                         "kernel.py:51",
            multiplier=1,
            operand_taints=(Taint("kv_pool", src=0),),
            operand_shapes=((8, 8, 2, 4),))],
        problems=[], outvar_taints=(None,))
    unit = _unit(art, mode="pallas_paged", axis_sizes={"data": 2, "model": 1},
                 page_size=8)
    findings = sharding_pass(unit)
    gather = [f for f in findings
              if f.code == "gspmd-gather-around-pallas-call"]
    assert len(gather) == 1
    assert gather[0].subject.endswith(":decode:kernels/paged_attention")
    assert "arg0" in gather[0].detail


def test_sharding_pass_skips_manual_shard_map_pallas_sites():
    # same sharded-pool operand as above, but the site sits inside a
    # shard_map region (PallasSite.manual): its operands are device-
    # local by construction, so the GSPMD-gather lint must not fire
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)],
                    specs=[PartitionSpec("data", None, None, None)])
    art._walk = WalkResult(
        buckets={c: 0 for c in TRAFFIC_CLASSES},
        pallas_sites=[PallasSite(
            name_and_src="_kernel at /x/src/repro/kernels/paged_attention/"
                         "kernel.py:51",
            multiplier=2,
            operand_taints=(Taint("kv_pool", src=0),),
            operand_shapes=((4, 8, 2, 4),),
            manual=True)],
        problems=[], outvar_taints=(None,))
    unit = _unit(art, mode="pallas_paged", axis_sizes={"data": 2, "model": 1},
                 page_size=8)
    assert [f for f in sharding_pass(unit)
            if f.code == "gspmd-gather-around-pallas-call"] == []


def test_sharding_pass_flags_unsharded_pool_page_dim():
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)])   # spec: replicated
    unit = _unit(art, mode="pallas_paged", axis_sizes={"data": 2},
                 data_axes=("data",), page_size=8)
    codes = {f.code for f in sharding_pass(unit)}
    assert "pool-page-dim-unsharded" in codes


def test_sharding_pass_silent_on_single_device():
    closed = jax.make_jaxpr(lambda p: p.sum())(jnp.zeros((8, 8, 2, 4)))
    art = _artifact(closed, [Taint("kv_pool", src=0)])
    assert sharding_pass(_unit(art, axis_sizes={"data": 1})) == []


def test_hygiene_pass_flags_donation_constants_and_f64():
    with enable_x64():
        closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(4, jnp.float64))
    art = _artifact(closed, [_kv()], expect=[True], donated=[False],
                    consts=(np.zeros(1 << 19, np.float32),))   # 2 MiB
    codes = {f.code for f in hygiene_pass(_unit(art))}
    assert codes == {"undonated-cache-buffer", "large-captured-constant",
                     "f64-promotion"}


def test_hygiene_pass_clean_artifact_is_silent():
    closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(4, jnp.float32))
    art = _artifact(closed, [_kv()], expect=[True], donated=[True])
    assert hygiene_pass(_unit(art)) == []


# --------------------------------------------------------- registry/baseline
def test_all_three_passes_are_registered():
    assert set(registered_passes()) >= {"traffic", "sharding", "hygiene"}
    with pytest.raises(ValueError, match="unknown analysis pass"):
        run_passes([], only=["nonesuch"])


def test_diff_baseline_gates_new_and_stale_not_info():
    base = {"sharding:gspmd:x": "known"}
    known = Finding("sharding", "gspmd", "x", "d")
    new = Finding("traffic", "traffic-drift", "y", "d")
    info = Finding("hygiene", "note", "z", "d", severity="info")
    got_new, fixed = diff_baseline([known, new, info], base)
    assert [f.key for f in got_new] == [new.key] and fixed == []
    # baselined finding fixed -> its entry is stale and must be deleted
    got_new, fixed = diff_baseline([info], base)
    assert got_new == [] and fixed == ["sharding:gspmd:x"]
    # info findings never enter a regenerated baseline
    assert baseline_payload([info])["findings"] == []


def test_checked_in_baseline_is_empty_after_shard_map_drain():
    # PR 6 baselined the single GSPMD-gather finding; PR 7 generalized
    # it into the mesh-parameterized pool-collective family (48 keys at
    # mesh 2/8/64/512); the device-local shard_map decode layout
    # drained every one of them.  The baseline must STAY empty — a new
    # pool collective belongs fixed, not allowlisted, and this test is
    # the tripwire against quietly re-baselining one.
    data = json.loads(BASELINE.read_text())
    assert data["schema"] == BASELINE_SCHEMA
    assert data["findings"] == [], [e["key"] for e in data["findings"]]
    assert load_baseline(BASELINE) == {}


# ------------------------------------------------- engine-level cross-checks
CROSS_ARCHS = ("qwen1.5-0.5b", "gemma2-9b", "recurrentgemma-2b")


def _audit_unit(arch, mode):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    kw = dict(max_len=32, max_batch=2)
    if mode != "contiguous":
        kw.update(paged=PagedCacheConfig(page_size=8), decode_backend=mode)
    return unit_from_engine(ServeEngine(model, params, **kw), arch)


@pytest.mark.parametrize("mode", ("contiguous", "gather", "pallas_paged"))
@pytest.mark.parametrize("arch", CROSS_ARCHS)
def test_static_audit_matches_telemetry_exactly(arch, mode):
    unit = _audit_unit(arch, mode)
    rep = decode_traffic_report(unit)
    assert rep["problems"] == []
    for k in GATED_CLASSES:
        assert rep["derived"].get(k, 0) == rep["expected"][k], (
            f"{arch}/{mode}: {k} derived {rep['derived'].get(k, 0)} "
            f"!= telemetry {rep['expected'][k]}")
    # solo topology: no pass may produce an error finding
    errors = [f for f in run_passes([unit]) if f.severity == "error"]
    assert errors == [], [f.key for f in errors]


# ------------------------------------------------------ HLO collective goldens
_META = ('metadata={op_name="%s" source_file="%s" source_line=%d}')


def _one(line, n_devices=None):
    (c,) = parse_collectives(line, n_devices=n_devices)
    return c


def test_all_gather_explicit_groups_and_ring_bytes():
    c = _one(
        '  %all-gather.1 = f32[8,16]{1,0} all-gather(f32[2,16]{1,0} %p.0), '
        'channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, '
        'use_global_device_ids=true, '
        + _META % ("jit(decode)/jit(main)/while/body/gather",
                   "/repo/src/repro/models/attention.py", 336))
    assert (c.kind, c.n_groups, c.group_size) == ("all-gather", 2, 4)
    assert c.result_bytes == 8 * 16 * 4 and c.operand_bytes == 2 * 16 * 4
    # ring all-gather: each device wires out*(g-1)/g bytes
    assert c.wire_bytes_per_device() == 8 * 16 * 4 * 3 // 4
    assert c.source_file.endswith("attention.py") and c.source_line == 336
    assert classify_collective(c, "gather") == "kv_pool"
    assert classify_collective(c, "contiguous") == "kv"


def test_all_reduce_iota_groups_and_state_classification():
    c = _one(
        '  %all-reduce.2 = f32[4,4]{1,0} all-reduce(f32[4,4]{1,0} %x), '
        'channel_id=2, replica_groups=[2,4]<=[4,2]T(1,0), to_apply=%add, '
        + _META % ("jit(decode)/jit(main)/while/body/gather",
                   "/repo/src/repro/models/rglru.py", 151))
    assert (c.kind, c.n_groups, c.group_size) == ("all-reduce", 2, 4)
    # ring all-reduce = reduce-scatter + all-gather: 2*in*(g-1)/g
    assert c.wire_bytes_per_device() == 2 * (4 * 4 * 4) * 3 // 4
    assert classify_collective(c, "pallas_paged") == "state_pool"
    assert classify_collective(c, "contiguous") == "state"


def test_reduce_scatter_metadata_less_float_is_activation():
    c = _one(
        '  %reduce-scatter.3 = f32[1,16]{1,0} reduce-scatter('
        'f32[8,16]{1,0} %y), replica_groups={{0,1,2,3,4,5,6,7}}, '
        'dimensions={0}, to_apply=%add')
    assert (c.kind, c.group_size) == ("reduce-scatter", 8)
    assert c.wire_bytes_per_device() == 8 * 16 * 4 * 7 // 8
    # a GSPMD reshard of an unnamed intermediate: never 'other' (which
    # would be an error finding), never silently a pool class
    assert classify_collective(c, "gather") == "activation"


def test_all_to_all_integer_payload_is_meta():
    c = _one(
        '  %all-to-all.4 = s32[4]{0} all-to-all(s32[4]{0} %idx), '
        'replica_groups={{0,1},{2,3}}, dimensions={0}, '
        + _META % ("jit(decode)/jit(main)/while/body/all_to_all",
                   "/repo/src/repro/models/attention.py", 100))
    assert (c.kind, c.n_groups, c.group_size) == ("all-to-all", 2, 2)
    assert c.wire_bytes_per_device() == 4 * 4 * 1 // 2
    # integer payload = block-table/length indirection, even at a KV site
    assert classify_collective(c, "gather") == "meta"


def test_collective_permute_wires_full_operand():
    c = _one(
        '  %collective-permute.5 = f32[2,8]{1,0} collective-permute('
        'f32[2,8]{1,0} %w), channel_id=5, source_target_pairs={{0,1},{1,0}}, '
        + _META % ("jit(prefill)/while/body/slice",
                   "/repo/src/repro/models/layers.py", 40))
    assert c.kind == "collective-permute"
    # point-to-point: the whole shard moves, group arithmetic is moot
    assert c.wire_bytes_per_device() == 2 * 8 * 4
    assert classify_collective(c, "gather") == "params"


def test_async_start_counts_once_done_is_skipped():
    text = (
        '  %all-gather-start.6 = (f32[2,4]{1,0}, f32[8,4]{1,0}) '
        'all-gather-start(f32[2,4]{1,0} %z), replica_groups={{0,1,2,3}}, '
        'dimensions={0}\n'
        '  %all-gather-done.7 = f32[8,4]{1,0} all-gather-done('
        '(f32[2,4]{1,0}, f32[8,4]{1,0}) %all-gather-start.6)\n')
    (c,) = parse_collectives(text)
    assert c.is_async and c.kind == "all-gather"
    # async-start result tuple is (operand, gathered): bill the payload
    assert c.result_bytes == 8 * 4 * 4
    assert c.wire_bytes_per_device() == 8 * 4 * 4 * 3 // 4


def test_empty_replica_groups_spans_all_devices_layout_parens_ok():
    # layout annotations put parens inside the operand region — the
    # depth scan must not cut the region short
    c = _one(
        '  %all-reduce.8 = f32[4]{0} all-reduce(f32[4]{0:T(4)} %f), '
        'replica_groups={}, to_apply=%add', n_devices=16)
    assert (c.n_groups, c.group_size) == (1, 16)
    assert c.operand_bytes == 4 * 4
    assert c.wire_bytes_per_device() == 2 * 16 * 15 // 16


def test_pool_dims_fallback_pins_metadata_less_pool_moves():
    c = _one('  %all-gather.9 = f32[40,8,2,4]{3,2,1,0} all-gather('
             'f32[5,8,2,4]{3,2,1,0} %pool), replica_groups={{0,1,2,3,4,5,6,7}}, '
             'dimensions={0}')
    pool_dims = {(40, 8, 2, 4): "kv_pool", (5, 8, 2, 4): "kv_pool"}
    # without the shape map this is just an unnamed float reshard...
    assert classify_collective(c, "pallas_paged") == "activation"
    # ...with it, a whole-pool materialization cannot hide
    assert classify_collective(c, "pallas_paged", pool_dims) == "kv_pool"


def test_transformer_cache_write_sites_classify_as_cache_not_params():
    line = ('  %all-reduce.10 = f32[1,1,32,4,16]{4,3,2,1,0} all-reduce('
            'f32[1,1,32,4,16]{4,3,2,1,0} %dus), replica_groups={{0,1}}, '
            'to_apply=%add, '
            + _META % ("jit(prefill)/jit(main)/while/body/"
                       "dynamic_update_slice",
                       "/repo/src/repro/models/transformer.py", 382))
    c = _one(line)
    assert classify_collective(c, "contiguous") == "kv"
    # a non-cache-write transformer.py site stays params
    c2 = _one(line.replace("dynamic_update_slice", "dot_general"))
    assert classify_collective(c2, "contiguous") == "params"


def test_paged_kernel_collectives_get_their_own_ledger_site():
    text = (
        '  %all-gather.11 = f32[40,8,2,4]{3,2,1,0} all-gather('
        'f32[5,8,2,4]{3,2,1,0} %kp), replica_groups={{0,1,2,3,4,5,6,7}}, '
        'dimensions={0}, '
        + _META % ("jit(decode)/jit(paged_decode_attention)/while/body/"
                   "dynamic_slice",
                   "/repo/src/repro/kernels/paged_attention/kernel.py", 157)
        + '\n'
        '  %all-gather.12 = f32[40,8,2,4]{3,2,1,0} all-gather('
        'f32[5,8,2,4]{3,2,1,0} %kp2), replica_groups={{0,1,2,3,4,5,6,7}}, '
        'dimensions={0}, '
        + _META % ("jit(decode)/jit(paged_decode_attention)/while/body/"
                   "dynamic_slice",
                   "/repo/src/repro/kernels/paged_attention/kernel.py", 157))
    rows = ledger_rows(parse_collectives(text), "pallas_paged")
    (row,) = rows
    assert row["site"] == "kernels/paged_attention"
    assert row["class"] == "kv_pool" and row["count"] == 2
    per_op = 40 * 8 * 2 * 4 * 4 * 7 // 8
    assert row["wire_bytes_per_device"] == 2 * per_op


# ------------------------------------------------------------ partition gates
def test_key_mesh_size_and_scope():
    assert key_mesh_size("partition:pool-collective:x@mesh=512") == 512
    assert key_mesh_size("sharding:gspmd:x") is None
    assert key_mesh_size("pass:code:mesh=8") is None     # suffix only
    # @mesh=N keys are scored iff N was audited
    assert key_in_scope("p:c:x@mesh=8", {2, 8})
    assert not key_in_scope("p:c:x@mesh=512", {2, 8})
    # mesh-independent keys are scored unless the jaxpr matrix was skipped
    assert key_in_scope("sharding:gspmd:x", {2, 8}, unmeshed_in_scope=True)
    assert not key_in_scope("sharding:gspmd:x", {2}, unmeshed_in_scope=False)
    # --partition-archs narrows meshed-key scope to the audited archs:
    # subjects lead with "<arch>/<mode>", so a qwen-only run cannot
    # declare another arch's @mesh=N entries stale
    qwen = "partition:pool-collective:qwen1.5-0.5b/gather:x@mesh=8"
    rg = "partition:pool-collective:recurrentgemma-2b/gather:x@mesh=8"
    assert key_in_scope(qwen, {8}, audited_archs=("qwen1.5-0.5b",))
    assert not key_in_scope(rg, {8}, audited_archs=("qwen1.5-0.5b",))
    assert key_in_scope(rg, {8}, audited_archs=None)   # full matrix ran
    # prefix match is on the full arch token, not a substring
    assert not key_in_scope(
        "partition:pool-collective:qwen1.5-0.5b-xl/gather:x@mesh=8",
        {8}, audited_archs=("qwen1.5-0.5b",))


def test_diff_baseline_leaves_out_of_scope_mesh_entries_alone():
    base = {"partition:pool-collective:x@mesh=2": "n",
            "partition:pool-collective:x@mesh=512": "n",
            "sharding:gspmd:x": "n"}
    at2 = Finding("partition", "pool-collective", "x@mesh=2", "d")
    # a --mesh 2 partition-only run: the @mesh=512 entry is unaudited
    # and the jaxpr matrix never ran — neither may be declared stale
    new, fixed = diff_baseline([at2], base, audited_meshes={2},
                               unmeshed_in_scope=False)
    assert new == [] and fixed == []
    # the full run with both sizes audited DOES retire fixed entries
    new, fixed = diff_baseline([at2], base, audited_meshes={2, 512},
                               unmeshed_in_scope=True)
    assert new == []
    assert fixed == ["partition:pool-collective:x@mesh=512",
                     "sharding:gspmd:x"]


def test_baseline_payload_preserves_out_of_scope_entries():
    f = Finding("partition", "pool-collective", "x@mesh=2", "d")
    payload = baseline_payload(
        [f], notes={f.key: "fresh note"},
        preserve={"partition:pool-collective:x@mesh=512": "kept verbatim"})
    entries = {e["key"]: e["note"] for e in payload["findings"]}
    assert entries == {"partition:pool-collective:x@mesh=2": "fresh note",
                       "partition:pool-collective:x@mesh=512":
                           "kept verbatim"}


def test_split_per_device_divides_exactly_or_complains():
    expected = {c: 0 for c in GATED_CLASSES}
    expected.update(kv_sweep_read=800, kv_append_write=80, state_read=102)
    per_dev, problems = split_per_device(
        expected, {"kv": 8, "state": 4}, "contiguous")
    assert per_dev["kv_sweep_read"] == 100
    assert per_dev["kv_append_write"] == 10
    assert problems == ["state_read: global 102 bytes/step not divisible "
                        "by the 'state' sharding factor 4"]
    # paged modes split by the pool leaf classes instead
    per_dev, problems = split_per_device(
        {**{c: 0 for c in GATED_CLASSES}, "gather_view_read": 64},
        {"kv_pool": 8}, "pallas_paged")
    assert per_dev["gather_view_read"] == 8 and problems == []


def test_sharded_leaf_factors_from_entry_shardings():
    class _Sh:                            # quacks like NamedSharding
        def __init__(self, split):
            self.split = split

        def shard_shape(self, shape):
            return (shape[0] // self.split,) + tuple(shape[1:])

    args = ({"kp": jax.ShapeDtypeStruct((40, 8, 2, 4), jnp.float32),
             "block": jax.ShapeDtypeStruct((8, 4), jnp.int32)},
            jax.ShapeDtypeStruct((8,), jnp.int32))
    shardings = ({"kp": _Sh(8), "block": _Sh(1)}, None)
    factors, problems = sharded_leaf_factors(args, shardings, {0: "cache"})
    assert factors == {"kv_pool": 8, "block": 1} and problems == []
    # two leaves of one class disagreeing on the factor is ill-defined
    args2 = ({"kp": jax.ShapeDtypeStruct((40, 2), jnp.float32),
              "vp": jax.ShapeDtypeStruct((40, 2), jnp.float32)},)
    _, problems = sharded_leaf_factors(
        args2, ({"kp": _Sh(8), "vp": _Sh(4)},), {0: "cache"})
    assert len(problems) == 1 and "kv_pool" in problems[0]


def _punit(mesh_size, per_device, mode="pallas_paged"):
    return PartitionUnit(
        label=f"qwen1.5-0.5b/{mode}/mesh{mesh_size}",
        cfg_name="qwen1.5-0.5b", mode=mode, mesh_size=mesh_size,
        live=mesh_size, ctx=32, collectives={},
        bill={"global": {}, "per_device": per_device, "leaf_factors": {}})


def test_invariance_gate_flags_per_device_growth_only():
    flat = {c: 0 for c in GATED_CLASSES}
    flat.update(kv_sweep_read=128, state_read=32)
    grown = dict(flat, state_read=256)    # state bill grew with the mesh
    ok = invariance_findings([_punit(2, flat), _punit(8, flat),
                              _punit(64, flat)])
    assert ok == []
    bad = invariance_findings([_punit(2, flat), _punit(8, grown)])
    assert [f.code for f in bad] == ["per-device-variance"]
    assert bad[0].subject == "qwen1.5-0.5b/pallas_paged:state_read@mesh=8"
    assert bad[0].severity == "error"
    # different (cfg, mode) pairs never compare against each other
    assert invariance_findings(
        [_punit(2, flat), _punit(8, grown, mode="gather")]) == []


@pytest.mark.slow_serve
def test_partition_bill_invariant_across_real_meshes(tmp_path):
    """2-vs-8-vs-64 on real engine artifacts: lower the qwen matrix
    under abstract meshes in a subprocess (forced device count) and
    assert the per-device decode bill is identical at every size."""
    out = tmp_path / "partition.json"
    repo = pathlib.Path(__file__).parent.parent
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--mesh", "2", "--mesh",
         "8", "--mesh", "64", "--partition-only", "--partition-archs",
         "qwen1.5-0.5b", "--json", str(out)],
        capture_output=True, text=True, env=env, cwd=repo)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    data = json.loads(out.read_text())
    assert not [f for f in data["findings"]
                if f["code"] == "per-device-variance"], proc.stdout
    bills = {}
    for label, u in data["partition"].items():
        arch, mode, mesh = label.split("/")
        bills.setdefault(mode, {})[int(mesh[len("mesh"):])] = \
            u["bill"]["per_device"]
    assert set(bills) == {"contiguous", "gather", "pallas_paged"}
    for mode, by_mesh in bills.items():
        assert set(by_mesh) == {2, 8, 64}
        assert by_mesh[2] == by_mesh[8] == by_mesh[64], mode
        assert any(by_mesh[2].values()), f"{mode}: empty per-device bill"
