"""Placement layer: geometry invariants across every mapping policy."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.dram import DRAMSpec
from repro.core.placement import (PLACEMENT_POLICIES, PlacementError,
                                  StreamGeometry, build_placement,
                                  fitting_spec)

STREAMS = (
    StreamGeometry("kv:groups0", n_pages=24, page_bytes=8192, shards=2,
                   reserved_per_shard=2),
    StreamGeometry("state:tail0", n_pages=12, page_bytes=640, shards=2,
                   reserved_per_shard=2),
)
PARAM_BYTES = 50_000


def test_stream_geometry_validation():
    with pytest.raises(ValueError, match="n_pages"):
        StreamGeometry("x", n_pages=0, page_bytes=1)
    with pytest.raises(ValueError, match="shards"):
        StreamGeometry("x", n_pages=5, page_bytes=1, shards=2)
    assert StreamGeometry("x", n_pages=6, page_bytes=1, shards=2).ext == 3


def test_unknown_policy_raises():
    spec = fitting_spec(STREAMS, param_bytes=PARAM_BYTES)
    with pytest.raises(PlacementError, match="unknown placement policy"):
        build_placement("hashed", spec, STREAMS)


@pytest.mark.parametrize("policy", PLACEMENT_POLICIES)
def test_every_page_mapped_inside_module(policy):
    """Core geometry contract: every page gets a non-empty in-bounds row
    interval, disjoint streams never share a *byte* (row sharing between
    consecutive sub-row pages is allowed), and the alloc bounds cover
    params + every page."""
    spec = fitting_spec(STREAMS, param_bytes=PARAM_BYTES)
    pl = build_placement(policy, spec, STREAMS, param_bytes=PARAM_BYTES)
    assert pl.param_lo == 0
    assert pl.param_hi == -(-PARAM_BYTES // spec.row_bytes)
    for si, g in enumerate(STREAMS):
        for pid in range(g.n_pages):
            lo, hi = pl.page_rows(si, pid)
            assert 0 <= lo <= hi < spec.n_rows
            # a page spans exactly the rows its byte size needs
            assert hi - lo <= -(-g.page_bytes // spec.row_bytes)
    assert 0 <= pl.alloc_lo < pl.alloc_hi <= spec.n_rows
    mask = np.zeros((spec.n_rows,), bool)
    pl.touch_params(mask)
    for si, g in enumerate(STREAMS):
        pl.touch(mask, si, range(g.n_pages))
    assert not mask[:pl.alloc_lo].any()
    assert not mask[pl.alloc_hi:].any()
    assert pl.rows_used() == mask.sum()


def test_row_major_is_contiguous_and_interleaved_is_spread():
    spec = fitting_spec(STREAMS, param_bytes=PARAM_BYTES)
    rm = build_placement("row-major", spec, STREAMS,
                         param_bytes=PARAM_BYTES)
    bi = build_placement("bank-interleaved", spec, STREAMS,
                         param_bytes=PARAM_BYTES)
    # row-major packs everything into one dense run from row 0
    assert rm.alloc_lo == 0
    assert rm.alloc_rows == rm.rows_used()
    # interleaving spreads the same pages across every bank's row span,
    # widening the PAAR allocation without using more rows
    assert bi.alloc_rows > rm.alloc_rows
    assert bi.rows_used() <= rm.rows_used() + spec.n_banks * spec.n_channels


def test_slot_colocation_groups_equal_local_indices():
    """Pages with equal per-shard local index across streams must land
    closer together than row-major's stream-at-a-time packing puts
    them (the refresh-aware co-location the policy exists for)."""
    spec = fitting_spec(STREAMS, param_bytes=PARAM_BYTES)
    rm = build_placement("row-major", spec, STREAMS,
                         param_bytes=PARAM_BYTES)
    sc = build_placement("slot-colocated", spec, STREAMS,
                         param_bytes=PARAM_BYTES)

    def spread(pl, local):
        rows = []
        for si, g in enumerate(STREAMS):
            for shard in range(g.shards):
                lo, hi = pl.page_rows(si, shard * g.ext + local)
                rows += [lo, hi]
        return max(rows) - min(rows)

    locals_ = range(min(g.ext for g in STREAMS))
    assert sum(spread(sc, l) for l in locals_) < \
        sum(spread(rm, l) for l in locals_)


def test_sequential_overflow_raises():
    tiny = DRAMSpec(capacity_bytes=8 * 2 * 4 * 2048)   # 64 rows
    big = (StreamGeometry("kv:groups0", n_pages=128, page_bytes=8192),)
    with pytest.raises(PlacementError, match="overflows"):
        build_placement("row-major", tiny, big)


def test_bank_overflow_raises():
    tiny = DRAMSpec(capacity_bytes=8 * 2 * 4 * 2048)   # 4 rows/bank
    big = (StreamGeometry("kv:groups0", n_pages=64, page_bytes=8192),)
    with pytest.raises(PlacementError, match="bank-interleaved: bank"):
        build_placement("bank-interleaved", tiny, big)


@given(
    half_pages=st.integers(1, 40),
    page_bytes=st.sampled_from([64, 640, 2048, 8192, 10000]),
    param_bytes=st.integers(0, 200_000),
)
@settings(max_examples=20, deadline=None)
def test_fitting_spec_fits_every_policy(half_pages, page_bytes, param_bytes):
    streams = (
        StreamGeometry("kv:groups0", n_pages=2 * half_pages,
                       page_bytes=page_bytes, shards=2),
        StreamGeometry("state:tail0", n_pages=2 * half_pages,
                       page_bytes=640, shards=2),
    )
    spec = fitting_spec(streams, param_bytes=param_bytes)
    for policy in PLACEMENT_POLICIES:
        pl = build_placement(policy, spec, streams,
                             param_bytes=param_bytes)
        assert pl.alloc_hi <= spec.n_rows
