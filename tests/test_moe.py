"""MoE: virtual-expert-split exactness + routing properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.models import moe as M
from repro.models.config import ModelConfig

CFG = ModelConfig("t", "moe", 2, 64, 4, 2, 128, 256, n_experts=4,
                  experts_per_token=2, moe_capacity_factor=64.0,
                  dtype="float32")


def _split_params(p1, e, d, f, s):
    """Reshape unsplit expert weights into the virtual-split layout."""
    return {
        "router": p1["router"],
        "wi": p1["wi"].reshape(e, d, s, f // s).swapaxes(1, 2)
                      .reshape(s * e, d, f // s),
        "wg": p1["wg"].reshape(e, d, s, f // s).swapaxes(1, 2)
                      .reshape(s * e, d, f // s),
        "wo": p1["wo"].reshape(e, s, f // s, d).reshape(s * e, f // s, d),
    }


@pytest.mark.parametrize("s", [2, 4])
def test_virtual_split_is_exact(s, rng):
    """The layout transform changes no math: same weights reshaped into
    s virtual experts produce identical outputs and aux loss."""
    cfg_s = dataclasses.replace(CFG, moe_virtual_split=s)
    p1 = M.moe_init(jax.random.key(0), CFG, jnp.float32)
    p2 = _split_params(p1, 4, 64, 128, s)
    x = jnp.asarray(rng.standard_normal((2, 8, 64)), jnp.float32)
    y1, a1 = M.moe_apply(p1, CFG, x)
    y2, a2 = M.moe_apply(p2, cfg_s, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    assert float(a1) == pytest.approx(float(a2), abs=1e-6)


def test_capacity_dropping_reduces_output(rng):
    """With capacity factor << 1, overflow tokens drop to zero output."""
    tight = dataclasses.replace(CFG, moe_capacity_factor=0.05)
    p = M.moe_init(jax.random.key(0), CFG, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, 64)), jnp.float32)
    y_full, _ = M.moe_apply(p, CFG, x)
    y_tight, _ = M.moe_apply(p, tight, x)
    norm_full = float(jnp.linalg.norm(y_full))
    norm_tight = float(jnp.linalg.norm(y_tight))
    assert norm_tight < norm_full


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_moe_grads_finite(seed):
    p = M.moe_init(jax.random.key(seed % 1000), CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(seed), (2, 8, 64))

    def loss(p):
        y, aux = M.moe_apply(p, CFG, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
