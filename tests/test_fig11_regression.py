"""Regression pin for the Fig. 11 RTC-vs-SmartRefresh comparison
(benchmarks/fig11_smartrefresh).

fig10 has been pinned since PR 1; this pins the other calibrated
figure.  Two layers of assertion per co-run CNN mix on the 8 GB module:

* a tight pin (±0.02) on the CURRENT calibration of both variants'
  DRAM-energy savings, so silent drift in the energy/refresh models is
  caught by CI;
* the paper's qualitative Section VI-B claim: full-RTC beats
  SmartRefresh on every mix, by a margin that grows as the mix gets
  lighter (LeNet-only at the top).  The quantitative delta currently
  spans 0.50..1.00 against the paper's ~0.28..0.96 text anchor — the
  calibration gap is tracked in the benchmark docstring, so only the
  ordering and positivity are treated as paper-anchored here.
"""
import pytest

from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import MODULE_8GB
from repro.core.rtc import Variant, evaluate
from repro.core.workload import from_cnn, merge

# mix label -> ((cnn, count), ...) per Section VI-B, 60 fps co-run
MIXES = {
    "LN": (("lenet", 1),),
    "GN": (("googlenet", 1),),
    "AN": (("alexnet", 1),),
    "AN+GN": (("alexnet", 1), ("googlenet", 1)),
    "2AN+2GN+LN": (("alexnet", 2), ("googlenet", 2), ("lenet", 1)),
}

# mix -> (full-RTC savings, SmartRefresh savings) current calibration.
# Re-verified after PR 9's merge() fix (row_utilization is now the
# traffic-weighted harmonic mean instead of a bare min): every CNN in
# these mixes runs the from_cnn default row_utilization=0.5, and a
# weighted harmonic mean of equal values is that value, so the pins are
# unchanged — the fix only moves mixes whose members *differ* in
# utilization (exercised in tests/test_workload.py).
EXPECTED = {
    "LN": (0.975, -0.022),
    "GN": (0.906, -0.015),
    "AN": (0.738, 0.005),
    "AN+GN": (0.695, 0.008),
    "2AN+2GN+LN": (0.530, 0.026),
}
CALIBRATION_TOL = 0.02


def _savings(label):
    ws = []
    for cnn, n in MIXES[label]:
        ws.extend([from_cnn(CNN_ZOO[cnn], fps=60)] * n)
    wl = merge(label, *ws)
    alloc = allocate_workload(MODULE_8GB, {"data": wl.footprint_bytes})
    rtc = evaluate(MODULE_8GB, wl, Variant.FULL_RTC, alloc)
    smart = evaluate(MODULE_8GB, wl, Variant.SMART_REFRESH, alloc)
    return rtc.dram_savings, smart.dram_savings


@pytest.mark.parametrize("label", sorted(MIXES))
def test_fig11_savings_pinned(label):
    rtc, smart = _savings(label)
    exp_rtc, exp_smart = EXPECTED[label]
    assert rtc == pytest.approx(exp_rtc, abs=CALIBRATION_TOL), (
        f"{label}: full-RTC drifted from pinned calibration: "
        f"{rtc:.3f} vs {exp_rtc:.3f}")
    assert smart == pytest.approx(exp_smart, abs=CALIBRATION_TOL), (
        f"{label}: SmartRefresh drifted from pinned calibration: "
        f"{smart:.3f} vs {exp_smart:.3f}")


def test_fig11_rtc_beats_smartrefresh_on_every_mix():
    """Paper Section VI-B: RTC saves more DRAM energy than SmartRefresh
    for every co-run mix, with the margin largest for LeNet-only."""
    deltas = {label: rtc - smart
              for label, (rtc, smart) in
              ((lab, _savings(lab)) for lab in MIXES)}
    assert all(d > 0 for d in deltas.values()), deltas
    assert deltas["LN"] == max(deltas.values())
    assert deltas["2AN+2GN+LN"] == min(deltas.values())
