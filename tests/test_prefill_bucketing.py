"""Cross-arch padding-equivalence suite for length-bucketed prefill.

The contract under test: right-padding a prompt up to a bucket and
prefilling through ``model.prefill(..., lengths=...)`` is *bit-identical*
to prefilling the unpadded prompt — the logits at ``length-1``, the
first sampled token, every cache/recurrent-state row below ``length``
(attention KV rows, ssm/rglru conv tails and hidden states), and the
decode continuation from the handed-off cache.  This is what lets
:class:`repro.serve.ServeEngine` bound its number of lowered prefill
executables by the bucket-ladder size instead of the traffic's length
distribution (the paper's "predictable access pattern" requirement at
the compiler level) without perturbing a single generation.

Exercised per family: causal + sliding-window attention (ring and
append caches), Mamba chunked selective scan, RG-LRU associative scan,
and dropless-MoE dispatch — i.e. all 10 ``repro.configs`` entries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import TransformerLM
from repro.serve import PrefillBuckets, ServeEngine

MAX_LEN = 24          # decode-cache length handed to model.prefill
MAX_PLEN = 12         # property-test prompt lengths: 1..MAX_PLEN
LADDER = (4, 8, 16)   # test bucket ladder (smallest-fit selection)

_CACHED = {}


def _arch(arch):
    """(model, params, jitted prefill, jitted decode) — cached per arch
    so property examples reuse executables instead of recompiling."""
    if arch not in _CACHED:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = model.init(jax.random.key(0))
        prefill = jax.jit(
            lambda p, t, n=None: model.prefill(p, t, MAX_LEN, lengths=n))
        _CACHED[arch] = (model, params, prefill, jax.jit(model.decode_step))
    return _CACHED[arch]


def _assert_trees_equal(ref, got, msg):
    leaves_r = jax.tree_util.tree_flatten_with_path(ref)[0]
    leaves_g = jax.tree_util.tree_leaves(got)
    assert len(leaves_r) == len(leaves_g)
    for (path, a), b in zip(leaves_r, leaves_g):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{msg}: cache leaf {jax.tree_util.keystr(path)}")


def _check_arch(arch, plen):
    model, params, prefill, decode = _arch(arch)
    cfg = model.cfg
    bucket = next(b for b in LADDER if plen <= b)
    rng = np.random.default_rng(plen)
    toks = rng.integers(0, cfg.vocab_size, (2, plen)).astype(np.int32)
    padded = np.zeros((2, bucket), np.int32)
    padded[:, :plen] = toks
    lengths = jnp.full((2,), plen, jnp.int32)

    ref_logits, ref_cache = prefill(params, jnp.asarray(toks))
    pad_logits, pad_cache = prefill(params, jnp.asarray(padded), lengths)

    # logits at length-1 and the first (greedy) sampled token
    np.testing.assert_array_equal(
        np.asarray(ref_logits), np.asarray(pad_logits),
        err_msg=f"{arch} plen={plen} bucket={bucket}: prefill logits")
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(ref_logits, -1)),
        np.asarray(jnp.argmax(pad_logits, -1)),
        err_msg=f"{arch} plen={plen}: first token")

    # every cache row: rows below length hold the prompt state, rows at
    # or above it are zero on BOTH sides (masked scatter == fresh cache)
    _assert_trees_equal(ref_cache, pad_cache,
                        f"{arch} plen={plen} bucket={bucket}")

    # the hand-off continues identically: greedy-decode a couple of
    # steps from each cache, starting at pos=length
    tok_r = jnp.argmax(ref_logits, -1).astype(jnp.int32)
    tok_p = jnp.argmax(pad_logits, -1).astype(jnp.int32)
    cache_r, cache_p = ref_cache, pad_cache
    for i in range(2):
        lg_r, cache_r = decode(params, cache_r, tok_r, jnp.asarray(plen + i))
        lg_p, cache_p = decode(params, cache_p, tok_p, jnp.asarray(plen + i))
        np.testing.assert_array_equal(
            np.asarray(lg_r), np.asarray(lg_p),
            err_msg=f"{arch} plen={plen}: decode step {i} logits")
        tok_r = jnp.argmax(lg_r, -1).astype(jnp.int32)
        tok_p = jnp.argmax(lg_p, -1).astype(jnp.int32)


@given(plen=st.integers(1, MAX_PLEN))
@settings(max_examples=4, deadline=None)
def test_padded_prefill_bit_identical_all_archs(plen):
    """Property: for every configured arch, bucket-padded prefill is
    bit-identical to unpadded prefill (logits at length-1, first token,
    all cache rows, decode continuation)."""
    for arch in ARCH_IDS:
        _check_arch(arch, plen)


def test_mixed_lengths_one_executable_per_bucket():
    """One batched padded prefill serves MIXED real lengths: the length
    vector is a runtime argument, not part of the lowered shape."""
    model, params, prefill, _ = _arch("qwen1.5-0.5b")
    cfg = model.cfg
    rng = np.random.default_rng(0)
    plens = [3, 7, 2]
    bucket = 8
    padded = np.zeros((len(plens), bucket), np.int32)
    rows = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in plens]
    for i, r in enumerate(rows):
        padded[i, :r.shape[0]] = r
    got, _ = prefill(params, jnp.asarray(padded),
                     jnp.asarray(plens, jnp.int32))
    for i, r in enumerate(rows):
        ref, _ = prefill(params, jnp.asarray(r[None]))
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(ref[0]),
                                      err_msg=f"row {i} (plen={plens[i]})")


# ---------------------------------------------------------------------------
# engine-level: bucketed == unbucketed serving, bounded executables
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_bucketed_serving_matches_unbucketed(arch):
    """Acceptance: a mixed-length workload through the bucketed engine
    reproduces per-length (unbucketed) serving bit-for-bit, on every
    arch, while lowering at most len(ladder) prefill executables."""
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (3, 9, 5)]

    bucketed = ServeEngine(model, params, max_len=16, max_batch=2,
                           buckets=(4, 8, 16))
    # an exact-length ladder degenerates to per-length (unbucketed)
    # prefill: every prompt "bucket" is its own length
    exact = ServeEngine(model, params, max_len=16, max_batch=2,
                        buckets=range(1, 17))
    out_b = bucketed.serve(prompts, 3)
    out_e = exact.serve(prompts, 3)
    for i, (a, b) in enumerate(zip(out_b, out_e)):
        np.testing.assert_array_equal(a, b, err_msg=f"{arch} request {i}")
    assert bucketed.prefill_executables <= len(bucketed.buckets.ladder)
    assert bucketed.buckets.real_tokens == sum(len(p) for p in prompts)


def test_compile_count_bounded_by_buckets_hit():
    """Regression: serving 15 distinct prompt lengths lowers exactly one
    prefill executable per bucket HIT — not one per distinct length."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=32, max_batch=4,
                         buckets=(4, 8, 16, 32))
    rng = np.random.default_rng(2)
    lens = list(range(3, 18))                  # 15 distinct lengths
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    engine.serve(prompts, 2)
    buckets_hit = {engine.buckets.bucket_for(n) for n in lens}
    assert buckets_hit == {4, 8, 16, 32}
    assert engine.prefill_executables == len(buckets_hit)
    assert engine.prefill_executables < len(set(lens))
    assert engine.buckets.hits == {4: 2, 8: 4, 16: 8, 32: 1}


# ---------------------------------------------------------------------------
# PrefillBuckets policy
# ---------------------------------------------------------------------------
def test_bucket_ladder_policy():
    b = PrefillBuckets.powers_of_two(100, min_bucket=8)
    assert b.ladder == (8, 16, 32, 64, 100)
    assert b.bucket_for(1) == 8
    assert b.bucket_for(8) == 8
    assert b.bucket_for(9) == 16
    assert b.bucket_for(65) == 100
    with pytest.raises(ValueError, match="exceeds top bucket"):
        b.bucket_for(101)
    # rungs above max_len are clipped; max_len is always the top rung
    c = PrefillBuckets((4, 8, 64), max_len=20)
    assert c.ladder == (4, 8, 20)
    with pytest.raises(ValueError, match="positive"):
        PrefillBuckets((0, 4))
    with pytest.raises(ValueError, match="min_bucket"):
        PrefillBuckets.powers_of_two(64, min_bucket=0)

    b.record(5, 8)
    b.record(20, 32)
    assert b.real_tokens == 25 and b.padded_tokens == 40
    assert b.pad_waste == pytest.approx(1 - 25 / 40)
    assert b.stats()["hits"][8] == 1
    assert "pad waste" in b.summary()


def test_engine_rejects_mis_sized_ladder():
    """A pre-built ladder must top out at exactly the engine max_len:
    shorter strands admissible prompts mid-serve, taller lowers shapes
    the cache can never use.  (Raw sequences are auto-clipped.)"""
    model, params, _, _ = _arch("qwen1.5-0.5b")
    for ladder in ((8,), (8, 64)):
        with pytest.raises(ValueError, match="max_len"):
            ServeEngine(model, params, max_len=32, max_batch=1,
                        buckets=PrefillBuckets(ladder))
    # scalar 0-d array params stay call-wide values (not sequences)
    engine = ServeEngine(model, params, max_len=16, max_batch=1,
                         buckets=(8, 16))
    prompt = [np.arange(3, dtype=np.int32) % model.cfg.vocab_size]
    a = engine.serve(prompt, 3, temperature=np.float32(50.0), seed=4)
    b = engine.serve(prompt, 3, temperature=50.0, seed=4)
    np.testing.assert_array_equal(a[0], b[0])


def test_telemetry_accounts_true_lengths_not_padded():
    """Prefill traffic in the RTC profile comes from TRUE prompt
    lengths; bucket padding is visible only as pad-waste."""
    from repro.serve import ServeTelemetry, TrafficModel
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=16, max_batch=2,
                         buckets=(4, 8, 16))
    tele = ServeTelemetry(TrafficModel.from_config(
        get_config("qwen1.5-0.5b"), max_len=4096))
    rng = np.random.default_rng(3)
    plens = (3, 5, 9)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in plens]
    engine.serve(prompts, 2, telemetry=tele)
    assert tele.prefill_tokens == sum(plens)          # true lengths
    assert tele.prefill_padded_tokens == 4 + 8 + 16   # bucketed lengths
    assert tele.prefill_pad_waste == pytest.approx(1 - 17 / 28)
    assert engine.buckets.stats()["pad_waste"] == tele.prefill_pad_waste
