"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and
benchmarks must see the single real CPU device; only
``repro.launch.dryrun`` forces 512 host devices (in its own process).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
