"""Prefix-sharing / copy-on-write acceptance suite (PR 10).

The contract under test: with ``PagedCacheConfig(sharing=...)`` the
engine serves any workload **bit-identically** to the unshared engine
(same outputs, same lowered executables) while N same-prefix requests
allocate the shared prefix pages **once** — the saving shows up in the
page table's allocation stats, in telemetry's ``prefix_hit`` traffic
class (whose exact-sum invariant against the unshared total is pinned
here, including across preempt/restore), and in the page-access trace's
per-step row set.  Also covers the duplicate-request-id rejection and
the opt-in suffix-feed mechanism.
"""
import collections

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.trace import PageAccessTrace
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, PrefixSharingConfig, ServeEngine,
                         ServeTelemetry, TrafficModel)
from repro.serve.paging import prefix_page_keys

PAGE = 8

_CACHED = {}


def _arch(arch):
    if arch not in _CACHED:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = model.init(jax.random.key(0))
        _CACHED[arch] = (cfg, model, params)
    return _CACHED[arch]


def _engine(arch, sharing, *, max_len=32, max_batch=3, max_ctx=32,
            resident_pages=None, page_size=PAGE):
    cfg, model, params = _arch(arch)
    return cfg, ServeEngine(
        model, params, max_len=max_len, max_batch=max_batch,
        paged=PagedCacheConfig(page_size=page_size, max_ctx=max_ctx,
                               resident_pages=resident_pages,
                               sharing=sharing))


def _tele(cfg, **kw):
    return ServeTelemetry(
        TrafficModel.from_config(cfg, max_len=32, page_size=PAGE), **kw)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


# ---------------------------------------------------------------------------
# hash scheme
# ---------------------------------------------------------------------------
def test_prefix_keys_chain_properties():
    """Chained content hashing: a page key covers its whole prefix."""
    rng = np.random.default_rng(1)
    a = rng.integers(0, 1000, (20,)).astype(np.int32)
    ka = prefix_page_keys(a, 8)
    # deterministic
    assert prefix_page_keys(a.copy(), 8) == ka
    assert len(ka.full) == 2 and ka.tail is not None
    assert ka.whole != ka.tail or ka.whole == ka.tail  # whole defined
    assert ka.group == ka.full[0]
    # same first page, divergent second page: full[0] shared, rest not
    b = a.copy()
    b[10] += 1
    kb = prefix_page_keys(b, 8)
    assert kb.full[0] == ka.full[0]
    assert kb.full[1] != ka.full[1] and kb.tail != ka.tail
    assert kb.whole != ka.whole
    # chaining: a change inside page 0 invalidates EVERY later key
    c = a.copy()
    c[0] += 1
    kc = prefix_page_keys(c, 8)
    assert kc.full[0] != ka.full[0] and kc.full[1] != ka.full[1]
    assert kc.tail != ka.tail and kc.group != ka.group
    # a strict prefix extension shares all full-page keys
    kd = prefix_page_keys(a[:19], 8)
    assert kd.full == ka.full and kd.tail != ka.tail


def test_prefix_keys_short_and_aligned():
    toks = np.arange(5, dtype=np.int32)
    k = prefix_page_keys(toks, 8)        # shorter than one page
    assert k.full == () and k.tail is not None
    assert k.whole == k.tail and k.group == k.whole
    ka = prefix_page_keys(np.arange(16, dtype=np.int32), 8)  # aligned
    assert len(ka.full) == 2 and ka.tail is None
    assert ka.whole == ka.full[-1]


# ---------------------------------------------------------------------------
# allocation-once pin + bit identity
# ---------------------------------------------------------------------------
def test_same_prefix_allocates_prefix_pages_once():
    """Acceptance pin: N identical prompts register each physical page
    once; the other N-1 requests *attach* (refcount) instead of
    allocating, and first-write-past-shared forks private copies."""
    cfg, solo = _engine("qwen1.5-0.5b",
                        PrefixSharingConfig(memo_size=0), max_batch=3)
    prompt = _prompts(cfg, [12], seed=2)[0]
    solo.serve([prompt], 4, seed=1)
    s1 = dict(solo.page_table.stats)
    assert s1["pages_registered"] > 0 and s1["pages_attached"] == 0

    cfg, eng = _engine("qwen1.5-0.5b",
                       PrefixSharingConfig(memo_size=0), max_batch=3)
    out = eng.serve([prompt, prompt.copy(), prompt.copy()], 4, seed=1)
    s3 = dict(eng.page_table.stats)
    # the prefix pages were allocated exactly once...
    assert s3["pages_registered"] == s1["pages_registered"]
    # ...and attached by each of the two duplicate admissions
    assert s3["pages_attached"] == 2 * s1["pages_registered"]
    # decode past the shared region forked private tail copies
    assert s3["cow_forks"] > 0
    # duplicates generate identically (greedy default w/ seed applies
    # per-request keys only at temperature>0; these are greedy)
    np.testing.assert_array_equal(out[0], out[1])
    np.testing.assert_array_equal(out[0], out[2])


def _paired_serve(arch, lens, *, dup=True, temps=None, topks=None,
                  max_new=12, seed=11, sharing=None, resident_pages=None,
                  max_batch=3):
    cfg, off = _engine(arch, None, max_batch=max_batch,
                       resident_pages=resident_pages)
    cfg, on = _engine(arch, sharing or PrefixSharingConfig(),
                      max_batch=max_batch, resident_pages=resident_pages)
    prompts = _prompts(cfg, lens, seed=3)
    if dup:
        prompts[1] = prompts[0].copy()       # exact duplicate
        if len(prompts) > 2 and len(prompts[0]) > 2:
            prompts[2] = prompts[0][:len(prompts[0]) - 1].copy()
    kw = dict(temperature=temps, top_k=topks, seed=seed)
    a = off.serve(prompts, max_new, **kw)
    b = on.serve(prompts, max_new, **kw)
    return cfg, off, on, a, b


def test_sharing_bit_identical_qwen():
    """Sharing on vs off: identical outputs on a shared-prefix workload
    (one exact duplicate + one strict-prefix prompt + one unique), with
    the lowered prefill-executable count pinned equal."""
    cfg, off, on, a, b = _paired_serve(
        "qwen1.5-0.5b", [12, 12, 11, 5],
        temps=[0.0, 50.0, 50.0, 0.0], topks=[None, None, 5, None])
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"request {i}")
    assert on.prefill_executables == off.prefill_executables
    assert on.page_table.stats["pages_attached"] > 0


@pytest.mark.slow_serve
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sharing_bit_identical_all_archs(arch):
    """Acceptance: shared-prefix serving is bit-identical to unshared
    on every architecture (state archs and sub-page local windows must
    degrade silently, never perturb)."""
    cfg, off, on, a, b = _paired_serve(
        arch, [12, 12, 11, 5], temps=[0.0, 50.0, 50.0, 0.0],
        topks=[None, None, 5, None])
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"{arch} request {i}")
    assert on.prefill_executables == off.prefill_executables


def test_state_arch_degrades_silently():
    """recurrentgemma's recurrent state is rewritten every step and its
    smoke local windows are shorter than these prompts, so sharing must
    engage nothing — and change nothing."""
    cfg, off, on, a, b = _paired_serve(
        "recurrentgemma-2b", [12, 12, 10], temps=[0.0, 50.0, 0.0])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    st = on.page_table.stats
    assert st["pages_attached"] == 0 and st["cow_forks"] == 0


# ---------------------------------------------------------------------------
# full skip (whole-prompt memo)
# ---------------------------------------------------------------------------
def test_full_skip_on_exact_duplicate():
    """An exact duplicate prompt skips prefill entirely: every page
    attaches, the memoized logits replay, and the generation matches
    the first request's (greedy) without a second prefill dispatch."""
    cfg, eng = _engine("qwen1.5-0.5b", PrefixSharingConfig(), max_batch=2)
    prompt = _prompts(cfg, [12], seed=4)[0]
    tele = _tele(cfg)
    out = eng.serve([prompt, prompt.copy()], 8, telemetry=tele, seed=9)
    np.testing.assert_array_equal(out[0], out[1])
    assert tele.prefix_full_skips == 1
    assert eng.page_table.stats["full_attaches"] == 1
    # one bucket shape ever prefilled -> exactly one lowered executable
    assert eng.prefill_executables == 1
    # telemetry still books the skipped prefill's request accounting
    assert tele.n_prefills == 2


def test_cow_fork_without_memo():
    """With the memo disabled, duplicates dedup-attach and the first
    append past the shared region triggers a copy-on-write fork; the
    generation stays bit-identical to the unshared engine."""
    cfg, off, on, a, b = _paired_serve(
        "qwen1.5-0.5b", [11, 11], temps=[50.0, 50.0],
        sharing=PrefixSharingConfig(memo_size=0), max_batch=2)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    st = on.page_table.stats
    assert st["pages_attached"] > 0 and st["cow_forks"] > 0
    assert st["full_attaches"] == 0


# ---------------------------------------------------------------------------
# telemetry exact-sum invariant
# ---------------------------------------------------------------------------
class _AdmitRecorder(ServeTelemetry):
    def __init__(self, traffic, **kw):
        super().__init__(traffic, **kw)
        self.admits = []

    def record_admit_shared(self, plen, hit_layer_tokens, total_layer_tokens,
                            **kw):
        self.admits.append((plen, hit_layer_tokens, total_layer_tokens))
        super().record_admit_shared(plen, hit_layer_tokens,
                                    total_layer_tokens, **kw)


def test_telemetry_exact_sum_invariant():
    """Acceptance: hit bytes + computed (written) bytes == the unshared
    total, per admission and in aggregate — sharing re-classifies
    admission traffic, it never changes the sum."""
    cfg, eng = _engine("qwen1.5-0.5b",
                       PrefixSharingConfig(memo_size=0), max_batch=3)
    t = TrafficModel.from_config(cfg, max_len=32, page_size=PAGE)
    shared = _AdmitRecorder(t)
    prompts = _prompts(cfg, [12, 12, 9], seed=5)
    prompts[1] = prompts[0].copy()
    eng.serve(prompts, 6, telemetry=shared, seed=2)

    # same lengths, all-unique content: every page misses
    cfg, eng2 = _engine("qwen1.5-0.5b",
                        PrefixSharingConfig(memo_size=0), max_batch=3)
    unshared = _AdmitRecorder(t)
    eng2.serve(_prompts(cfg, [12, 12, 9], seed=6), 6,
               telemetry=unshared, seed=2)

    assert shared.prefix_hit_tokens > 0
    assert unshared.prefix_hit_tokens == 0
    # per admission: hit never exceeds total
    for plen, hit, total in shared.admits:
        assert 0 <= hit <= total
    # aggregate exact sum: (hit + written) bytes invariant across the
    # two runs because the per-request totals depend only on lengths
    assert (shared.prefix_hit_bytes_total + shared.admit_write_bytes_total
            == unshared.prefix_hit_bytes_total
            + unshared.admit_write_bytes_total)
    assert shared.prefix_hit_frac > 0.0


def test_no_double_count_across_preempt_restore():
    """A preempted-and-restored shared slot must not re-book admission
    traffic: exactly one record_admit_shared per request, and the
    exact-sum matches an ample-budget run of the same workload."""
    cfg, _, _ = _arch("qwen1.5-0.5b")
    prompts = _prompts(cfg, [12, 12, 9], seed=5)
    prompts[1] = prompts[0].copy()

    def run(resident_pages):
        cfg2, eng = _engine("qwen1.5-0.5b",
                            PrefixSharingConfig(memo_size=0), max_batch=3,
                            resident_pages=resident_pages)
        tele = _AdmitRecorder(
            TrafficModel.from_config(cfg2, max_len=32, page_size=PAGE))
        out = eng.serve(prompts, 14, seed=2, telemetry=tele)
        return out, tele

    ample_out, ample = run(None)
    tight_out, tight = run(6)            # forces preemption + offload
    assert tight.page_outs > 0 and tight.page_ins > 0
    for x, y in zip(ample_out, tight_out):
        np.testing.assert_array_equal(x, y)
    assert len(tight.admits) == len(prompts) == tight.prefix_admits
    assert (tight.prefix_hit_bytes_total + tight.admit_write_bytes_total
            == ample.prefix_hit_bytes_total + ample.admit_write_bytes_total)


def test_record_admit_shared_rejects_overcount():
    cfg, _, _ = _arch("qwen1.5-0.5b")
    tele = _tele(cfg)
    with pytest.raises(ValueError):
        tele.record_admit_shared(8, hit_layer_tokens=10, total_layer_tokens=9)


# ---------------------------------------------------------------------------
# request ids
# ---------------------------------------------------------------------------
def test_duplicate_request_ids_rejected():
    cfg, eng = _engine("qwen1.5-0.5b", None)
    prompts = _prompts(cfg, [5, 6, 7], seed=7)
    with pytest.raises(ValueError, match=r"indices 0 and 2"):
        eng.serve(prompts, 4, request_ids=[9, 3, 9])


def test_custom_request_ids_keep_input_order():
    """Out-of-order ids must not change scheduling outcomes: greedy
    outputs (sampling-key independent) under a tight budget match the
    default-id run, in input order — victim selection follows arrival
    order, not id order."""
    cfg, eng = _engine("qwen1.5-0.5b", None, resident_pages=6)
    prompts = _prompts(cfg, [12, 9, 11], seed=8)
    a = eng.serve(prompts, 14, seed=1)
    cfg, eng2 = _engine("qwen1.5-0.5b", None, resident_pages=6)
    b = eng2.serve(prompts, 14, seed=1, request_ids=[100, 5, 50])
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"request {i}")


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def test_prefix_schedule_batches_same_prefix():
    """On an interleaved two-group workload with a 2-slot batch, FIFO
    serves A,B then A,B (the group's pages die between batches —
    sharing is in-flight only), while the prefix schedule co-schedules
    A,A then B,B and actually attaches.  Outputs are schedule-
    independent (greedy)."""
    cfg, _, _ = _arch("qwen1.5-0.5b")
    base = _prompts(cfg, [12, 12], seed=9)
    prompts = [base[0], base[1], base[0].copy(), base[1].copy()]

    def run(schedule):
        cfg2, eng = _engine(
            "qwen1.5-0.5b",
            PrefixSharingConfig(schedule=schedule, memo_size=0),
            max_batch=2)
        out = eng.serve(prompts, 6, seed=3)
        return out, dict(eng.page_table.stats)

    out_f, st_f = run("fifo")
    out_p, st_p = run("prefix")
    assert st_p["pages_attached"] > st_f["pages_attached"]
    for x, y in zip(out_f, out_p):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# trace row set
# ---------------------------------------------------------------------------
def test_trace_row_set_shrinks_under_sharing():
    """The page-access trace dedups physical ids per step, so the
    shared serve's footprint and per-step touch totals are strictly
    smaller than its unshared twin's on a duplicate-prompt workload."""
    cfg, _, _ = _arch("qwen1.5-0.5b")
    prompts = _prompts(cfg, [12], seed=10)
    prompts = [prompts[0], prompts[0].copy()]

    def run(sharing):
        cfg2, eng = _engine("qwen1.5-0.5b", sharing, max_batch=2)
        trace = PageAccessTrace(eng.page_table.stream_names())
        tele = _tele(cfg2, trace=trace)
        out = eng.serve(prompts, 6, seed=4, telemetry=tele)
        return out, trace

    out_u, tr_u = run(None)
    out_s, tr_s = run(PrefixSharingConfig(memo_size=0))
    for x, y in zip(out_u, out_s):
        np.testing.assert_array_equal(x, y)
    assert tr_s.n_steps == tr_u.n_steps
    assert sum(tr_s.pages_touched()) < sum(tr_u.pages_touched())
    assert sum(tr_s.step_page_counts()) < sum(tr_u.step_page_counts())
    assert all(a <= b for a, b in zip(tr_s.step_page_counts(),
                                      tr_u.step_page_counts()))


# ---------------------------------------------------------------------------
# suffix feed (opt-in)
# ---------------------------------------------------------------------------
def test_suffix_feed_mechanism():
    """Opt-in suffix feed: a request extending a live request's full
    prefix pages attaches them and teacher-forces only its suffix; it
    emits the full requested generation length."""
    cfg, eng = _engine(
        "qwen1.5-0.5b",
        PrefixSharingConfig(suffix_feed=True, memo_size=0), max_batch=2)
    rng = np.random.default_rng(12)
    a = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
    b = np.concatenate([a[:8], rng.integers(
        0, cfg.vocab_size, (4,)).astype(np.int32)])   # shares page 0
    tele = _tele(cfg)
    out = eng.serve([a, b], 8, temperature=[50.0, 50.0], seed=6,
                    telemetry=tele)
    assert tele.prefix_suffix_feeds >= 1
    assert eng.page_table.stats["pages_attached"] > 0
    assert all(o.shape[0] == 8 for o in out)
