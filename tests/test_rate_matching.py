"""Algorithm 1 (RTT rate matching): unit + property tests.

The schedule has a clean arithmetic characterization (Euclidean rhythm);
hypothesis sweeps (N_a, N_r) and cross-checks all four implementations
(reference / lax.scan / closed form / Pallas kernel) plus the paper's
worked example (Fig. 5).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.rate_matching import (coalesced_access_fraction,
                                      implicit_fraction, period,
                                      ratematch_closed, ratematch_ref,
                                      ratematch_scan, schedule_stats)
from repro.kernels.rate_match.ops import schedule_bits


def test_paper_fig5_example():
    # N_a = 2, N_r = 4: alternate implicit / explicit (Fig. 5)
    assert ratematch_ref(2, 4) == [1, 0]


def test_matched_rates_all_implicit():
    assert ratematch_ref(7, 7) == [1]
    assert ratematch_ref(9, 3) == [1]


def test_zero_access_all_explicit():
    assert ratematch_ref(0, 5) == [0]
    assert period(0, 5) == 1


@given(st.integers(0, 500), st.integers(1, 500))
@settings(max_examples=200, deadline=None)
def test_implementations_agree(n_a, n_r):
    p = period(n_a, n_r)
    ref = ratematch_ref(n_a, n_r)
    scan = np.asarray(ratematch_scan(n_a, n_r, p)).tolist()
    closed = np.asarray(
        ratematch_closed(np.arange(1, p + 1), n_a, n_r)).tolist()
    pallas = np.asarray(schedule_bits(n_a, n_r, p)).tolist()
    assert ref == scan == closed == pallas


@given(st.integers(1, 400), st.integers(1, 400))
@settings(max_examples=150, deadline=None)
def test_density_is_exact(n_a, n_r):
    """Over one period, implicit slots == reduced N_a (when N_a < N_r):
    the schedule realizes exactly the implicit fraction min(1, Na/Nr)."""
    p, ones, zeros = schedule_stats(n_a, n_r)
    assert ones + zeros == p
    assert abs(ones / p - implicit_fraction(n_a, n_r)) < 1e-12


@given(st.integers(1, 300), st.integers(1, 300))
@settings(max_examples=100, deadline=None)
def test_no_starvation(n_a, n_r):
    """Explicit refreshes are spread (Bresenham property): within any
    window of ceil(P/zeros)+1 slots there is at least one explicit
    refresh when N_a < N_r — no row waits two periods."""
    if n_a >= n_r:
        return
    bits = ratematch_ref(n_a, n_r)
    p = len(bits)
    zeros = bits.count(0)
    if zeros == 0:
        return
    max_gap = -(-p // zeros) + 1
    doubled = bits + bits
    run = 0
    for b in doubled:
        if b == 1:
            run += 1
            assert run <= max_gap
        else:
            run = 0


def test_public_api_exports():
    """The public surface is consistent: everything the tests (and the
    simulator) import is in ``__all__`` and star-importable —
    ``schedule_stats`` used to be importable but unexported."""
    import repro.core.rate_matching as rm
    exported = set(rm.__all__)
    assert "schedule_stats" in exported
    for name in exported:
        assert hasattr(rm, name), name
    ns = {}
    exec("from repro.core.rate_matching import *", ns)
    assert exported <= set(ns)
    p, ones, zeros = ns["schedule_stats"](2, 4)
    assert (p, ones, zeros) == (2, 1, 1)


@given(st.integers(0, 10_000_000), st.integers(1, 10_000_000))
@settings(max_examples=50, deadline=None)
def test_module_scale_rates(n_a, n_r):
    """Fractions behave at real module scales (4M+ rows) without
    overflow (closed form uses int64 host math)."""
    f = implicit_fraction(n_a, n_r)
    x = coalesced_access_fraction(n_a, n_r)
    assert 0.0 <= f <= 1.0 and 0.0 <= x <= 1.0
    i = np.arange(1, 101)
    bits = np.asarray(ratematch_closed(i, n_a, n_r))
    assert set(np.unique(bits)).issubset({0, 1})
