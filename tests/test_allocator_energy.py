"""Allocator + DRAM geometry + energy model units/properties."""
import math

import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.allocator import Allocator, allocate_workload
from repro.core.dram import DRAMSpec, MODULE_2GB, MODULE_8GB, TempMode, chip
from repro.core.energy import DEFAULT_PARAMS, dram_power
from repro.core.workload import WorkloadProfile, from_cnn, merge
from repro.core.cnn_zoo import CNN_ZOO, cnn_profile


# ---------------------------------------------------------------------------
# DRAM geometry
# ---------------------------------------------------------------------------
def test_paper_row_count_consistency():
    """Section VI-B: an 8 GB module with 2048 B rows has 4,194,304 rows
    (the paper's SmartRefresh counter count)."""
    assert MODULE_8GB.n_rows == 4_194_304


def test_refresh_cadence():
    spec = MODULE_2GB
    assert spec.refresh_cmds_per_window == round(64e-3 / 7.8e-6)
    assert spec.rows_per_refresh_cmd * spec.refresh_cmds_per_window >= spec.n_rows


def test_extended_temperature_halves_retention():
    hot = DRAMSpec(capacity_bytes=MODULE_2GB.capacity_bytes,
                   temp=TempMode.EXTENDED)
    assert hot.effective_retention_s == MODULE_2GB.effective_retention_s / 2
    assert hot.refresh_rows_per_second == 2 * MODULE_2GB.refresh_rows_per_second


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------
def test_alloc_bounds_and_banks():
    alloc = Allocator(MODULE_2GB, policy="pack")
    alloc.alloc("w", 10 << 20)
    alloc.alloc("act", 1 << 20)
    m = alloc.map
    lo, hi = m.bounds()
    assert lo == 0 and hi == m.allocated_rows
    assert m.row_paar_refresh_fraction() == pytest.approx(
        m.allocated_rows / MODULE_2GB.n_rows)
    assert m.banks_touched() == 1  # packed: one bank suffices


def test_interleave_touches_all_banks():
    alloc = Allocator(MODULE_2GB, policy="interleave")
    alloc.alloc("w", 10 << 20)
    assert alloc.map.banks_touched() == \
        MODULE_2GB.n_banks * MODULE_2GB.n_channels


def test_alloc_oom():
    alloc = Allocator(MODULE_2GB)
    with pytest.raises(MemoryError):
        alloc.alloc("too-big", MODULE_2GB.capacity_bytes + 1)


@given(st.lists(st.integers(1, 50 << 20), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_alloc_regions_disjoint(sizes):
    alloc = Allocator(MODULE_8GB)
    for i, s in enumerate(sizes):
        alloc.alloc(f"r{i}", s)
    regions = sorted(alloc.map.regions.values(), key=lambda r: r.start_row)
    for a, b in zip(regions, regions[1:]):
        assert a.end_row <= b.start_row
    assert alloc.map.allocated_bytes == sum(sizes)


# ---------------------------------------------------------------------------
# Energy model
# ---------------------------------------------------------------------------
def test_refresh_power_scales_with_capacity():
    w = from_cnn(CNN_ZOO["alexnet"], 60)
    p2 = dram_power(MODULE_2GB, w)
    p8 = dram_power(MODULE_8GB, w)
    assert p8.refresh == pytest.approx(4 * p2.refresh, rel=1e-6)
    assert p8.io == pytest.approx(p2.io, rel=1e-6)  # traffic unchanged


def test_refresh_dominates_idle_small_footprint():
    """LeNet-style: refresh must dominate DRAM energy (>90%)."""
    w = from_cnn(CNN_ZOO["lenet"], 60)
    p = dram_power(MODULE_2GB, w)
    assert p.refresh_fraction > 0.9


@given(st.floats(0.25, 1.0))
@settings(max_examples=30, deadline=None)
def test_locality_scales_reads(loc):
    prof = cnn_profile("alexnet")
    w1 = from_cnn(prof, 60, locality=1.0)
    w2 = from_cnn(prof, 60, locality=loc)
    assert w2.read_bytes_per_iter == pytest.approx(
        w1.read_bytes_per_iter / loc, rel=1e-9)


def test_merge_traffic_adds():
    a = from_cnn(CNN_ZOO["alexnet"], 60)
    l = from_cnn(CNN_ZOO["lenet"], 60)
    m = merge("mix", a, l)
    assert m.footprint_bytes == a.footprint_bytes + l.footprint_bytes
    assert m.traffic_bytes_per_s == pytest.approx(
        a.traffic_bytes_per_s + l.traffic_bytes_per_s, rel=1e-9)


def test_lenet_footprint_anchor():
    """Section III-D: LeNet footprint ~1.06 MB at 100x100 input."""
    assert 0.9e6 <= CNN_ZOO["lenet"].footprint_bytes <= 1.2e6


def test_alexnet_row_coverage_anchor():
    """AN@60fps touches ~44% of a 2 GB module's rows per retention
    window (the Fig. 10a RTT operating point)."""
    w = from_cnn(CNN_ZOO["alexnet"], 60)
    frac = w.rows_accessed_per_window(MODULE_2GB) / MODULE_2GB.n_rows
    assert 0.80 <= frac <= 1.0  # near rate-matched, as the paper says
