"""Fault tolerance: exact restart, atomic checkpoints, preemption,
elastic re-mesh (CPU-scale integration tests of the production paths)."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.dist.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh
from repro.models.transformer import TransformerLM
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer


def _mk_trainer(tmp_path, ckpt_every=5, seed=0):
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    policy = ShardingPolicy.for_mesh(mesh)
    data = SyntheticLMData(cfg.vocab_size, batch=4, seq_len=16, seed=seed)
    return Trainer(model, AdamWConfig(lr=1e-3, total_steps=100), mesh,
                   policy, data, ckpt_dir=str(tmp_path),
                   ckpt_every=ckpt_every, seed=seed)


def test_loss_decreases(tmp_path):
    t = _mk_trainer(tmp_path)
    report = t.run(12)
    assert report.losses[-1] < report.losses[0]
    assert np.isfinite(report.losses).all()


def test_exact_restart_reproduces_trajectory(tmp_path):
    """Killed-and-restarted training == uninterrupted training, bit for
    bit: stateless data + full-state checkpoints."""
    full = _mk_trainer(tmp_path / "a").run(10).losses

    t1 = _mk_trainer(tmp_path / "b", ckpt_every=5)
    first = t1.run(5)             # checkpoints at step 5, then "dies"
    t2 = _mk_trainer(tmp_path / "b", ckpt_every=5)  # fresh process
    second = t2.run(5)
    assert second.resumed_from == 5
    resumed = first.losses + second.losses
    np.testing.assert_allclose(resumed, full, rtol=0, atol=0)


def test_checkpoint_atomicity_on_partial_write(tmp_path):
    """A leftover .tmp directory from a crashed writer is never picked
    up as the latest step."""
    t = _mk_trainer(tmp_path, ckpt_every=5)
    t.run(5)
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert store.latest_step(str(tmp_path)) == 5


def test_checkpoint_crc_detects_corruption(tmp_path):
    t = _mk_trainer(tmp_path, ckpt_every=5)
    t.run(5)
    # flip bytes in the array file
    path = tmp_path / "step_00000005" / "arrays.npz"
    raw = bytearray(path.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    path.write_bytes(bytes(raw))
    t2 = _mk_trainer(tmp_path)
    with pytest.raises(Exception):
        t2.run(1)


def test_preemption_checkpoints_and_exits(tmp_path):
    t = _mk_trainer(tmp_path, ckpt_every=100)
    t._flag_preempt()
    report = t.run(10)
    assert report.preempted and report.steps_run == 1
    assert store.latest_step(str(tmp_path)) == 1


def test_elastic_remesh_restore(tmp_path):
    """A checkpoint taken on one mesh restores onto a different mesh
    (restore reshards onto the new target shardings)."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    data = SyntheticLMData(cfg.vocab_size, batch=4, seq_len=16)

    mesh1 = make_mesh((1, 1), ("data", "model"))
    t1 = Trainer(model, AdamWConfig(total_steps=100), mesh1,
                 ShardingPolicy.for_mesh(mesh1), data,
                 ckpt_dir=str(tmp_path), ckpt_every=3)
    losses1 = t1.run(3).losses

    # "scale" to a new mesh (still 1 device on CPU, but a fresh mesh and
    # freshly-built sharded step) and resume
    mesh2 = make_mesh((1, 1), ("data", "model"))
    t2 = Trainer(model, AdamWConfig(total_steps=100), mesh2,
                 ShardingPolicy.for_mesh(mesh2), data,
                 ckpt_dir=str(tmp_path), ckpt_every=3)
    rep2 = t2.run(2)
    assert rep2.resumed_from == 3
    assert np.isfinite(rep2.losses).all()


def test_data_pipeline_deterministic():
    d = SyntheticLMData(1000, batch=4, seq_len=8, seed=7)
    a1, b1 = d.batch_at(13)
    a2, b2 = d.batch_at(13)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    a3, _ = d.batch_at(14)
    assert not np.array_equal(a1, a3)
    # labels are next-token shifted
    full_a, full_b = d.batch_at(0)
    np.testing.assert_array_equal(full_a[:, 1:], full_b[:, :-1])
