"""Serving engine (prefill / continuous batching / sampling / telemetry)
+ optimizer units."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dram import module
from repro.core.rtc import Variant, evaluate
from repro.models.transformer import TransformerLM
from repro.serve import ServeEngine, ServeTelemetry, TrafficModel
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, global_norm)

# randomly-initialized smoke models have near-degenerate logits (one
# dominant token); this temperature flattens them enough to exercise
# the stochastic path
HOT = 50.0


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(qwen):
    _, model, params = qwen
    return ServeEngine(model, params, max_len=32, max_batch=3)


@pytest.fixture(scope="module")
def solo_engine(qwen):
    """Same model, one batch slot: the per-sequence reference."""
    _, model, params = qwen
    return ServeEngine(model, params, max_len=32, max_batch=1)


@pytest.fixture(scope="module")
def mixed_prompts(qwen):
    cfg = qwen[0]
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in (5, 9, 3, 12, 7)]


# ---------------------------------------------------------------------------
# one-shot prefill
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mixtral-8x22b"])
def test_prefill_matches_decode_sweep(arch):
    """model.prefill (ONE full-sequence forward) must agree with the
    token-by-token decode path — logits and the continued generation.
    Covers the ring/append KV caches, recurrent (conv/ssm/rglru) state
    hand-off, and dropless MoE prefill dispatch."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    toks = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 7)).astype(np.int32)

    logits_p, cache_p = jax.jit(
        lambda p, t: model.prefill(p, t, 24))(params, jnp.asarray(toks))
    dec = jax.jit(model.decode_step)
    cache_d = model.init_cache(2, 24)
    for t in range(7):
        logits_d, cache_d = dec(params, cache_d,
                                jnp.asarray(toks[:, t]), jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=1e-4, atol=1e-4)
    tok_p = jnp.argmax(logits_p, -1).astype(jnp.int32)
    tok_d = jnp.argmax(logits_d, -1).astype(jnp.int32)
    for i in range(3):   # caches must be interchangeable going forward
        lp, cache_p = dec(params, cache_p, tok_p, jnp.asarray(7 + i))
        ld, cache_d = dec(params, cache_d, tok_d, jnp.asarray(7 + i))
        tok_p = jnp.argmax(lp, -1).astype(jnp.int32)
        tok_d = jnp.argmax(ld, -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok_p), np.asarray(tok_d))


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
def test_continuous_batching_matches_per_sequence(engine, solo_engine,
                                                  mixed_prompts):
    """5 mixed-length requests over 3 slots (forcing mid-flight
    admit/retire) must produce exactly the tokens each request gets
    when served alone."""
    batched = engine.serve(mixed_prompts, 6)
    for i, p in enumerate(mixed_prompts):
        alone = solo_engine.serve([p], 6)[0]
        np.testing.assert_array_equal(batched[i], alone)


def test_continuous_batching_temperature_schedule_independent(
        engine, solo_engine, mixed_prompts):
    """Sampling keys are (request, token-index)-addressed, so even the
    stochastic path is independent of slot scheduling."""
    batched = engine.serve(mixed_prompts, 6, temperature=HOT, seed=11)
    sequential = solo_engine.serve(mixed_prompts, 6, temperature=HOT, seed=11)
    for a, b in zip(batched, sequential):
        np.testing.assert_array_equal(a, b)


def test_eos_retirement_frees_slot(engine, solo_engine, mixed_prompts):
    """Retiring on EOS mid-flight must not disturb other requests."""
    ref = engine.serve(mixed_prompts, 6)
    eos = int(ref[0][1])   # second token of request 0 becomes "EOS"
    outs = engine.serve(mixed_prompts, 6, eos_id=eos)
    for got, full in zip(outs, ref):
        stop = np.where(full == eos)[0]
        want = full[:stop[0] + 1] if stop.size else full
        np.testing.assert_array_equal(got, want)
    padded = engine.generate(
        np.stack([p[:3] for p in mixed_prompts[:2]]), 6, eos_id=eos)
    assert padded.shape == (2, 6)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------
def test_serve_engine_greedy_deterministic(engine, mixed_prompts):
    prompts = np.stack([p[:3] for p in mixed_prompts[:3]])
    a = engine.generate(prompts, 8, temperature=0.0)
    b = engine.generate(prompts, 8, temperature=0.0)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 8)
    vocab = engine.model.cfg.vocab_size
    assert (a >= 0).all() and (a < vocab).all()


def test_serve_engine_sampling_deterministic_by_seed(engine, mixed_prompts):
    a = engine.serve(mixed_prompts, 8, temperature=HOT, seed=1)
    b = engine.serve(mixed_prompts, 8, temperature=HOT, seed=2)
    c = engine.serve(mixed_prompts, 8, temperature=HOT, seed=1)
    assert any(not np.array_equal(x, y) for x, y in zip(a, b))
    for x, y in zip(a, c):
        np.testing.assert_array_equal(x, y)


def test_first_token_respects_temperature(engine, mixed_prompts):
    """Seed-engine bug regression: the first emitted token used to be
    argmaxed unconditionally; it must go through the same sampler."""
    firsts = {
        int(engine.serve(mixed_prompts[:1], 1,
                         temperature=HOT, seed=s)[0][0])
        for s in range(8)
    }
    assert len(firsts) > 1


def test_per_request_sampling_params(engine, solo_engine, mixed_prompts):
    """temperature/top_k live on each request: a mixed greedy+temperature
    batch reproduces each request's solo generation bit-for-bit."""
    temps = [0.0, HOT, HOT, 0.0, HOT]
    topks = [None, None, 5, 3, None]
    mixed = engine.serve(mixed_prompts, 6, temperature=temps, top_k=topks,
                         seed=11)
    sequential = solo_engine.serve(mixed_prompts, 6, temperature=temps,
                                   top_k=topks, seed=11)
    for i, (a, b) in enumerate(zip(mixed, sequential)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    # greedy requests are key-independent -> comparable to a true solo
    # serve (request ids restart at 0, but greedy never draws a key)
    np.testing.assert_array_equal(
        mixed[0], solo_engine.serve([mixed_prompts[0]], 6)[0])
    # each request's params are isolated: request 1 matches the same
    # request position under an all-HOT call, request 3 (temp 0) matches
    # pure greedy serving regardless of its top_k
    hot_all = engine.serve(mixed_prompts, 6, temperature=HOT, seed=11)
    np.testing.assert_array_equal(mixed[1], hot_all[1])
    greedy_all = engine.serve(mixed_prompts, 6)
    np.testing.assert_array_equal(mixed[3], greedy_all[3])


def test_per_request_param_validation(engine, mixed_prompts):
    with pytest.raises(ValueError, match="temperature"):
        engine.serve(mixed_prompts[:2], 2, temperature=[0.0])
    with pytest.raises(ValueError, match="top_k"):
        engine.serve(mixed_prompts[:2], 2, top_k=[2, 0])


def test_temperature_rejected_like_top_k(engine, mixed_prompts):
    """A negative temperature flips the softmax ordering and NaN poisons
    every draw — both must be rejected up front with the offending
    request index named, symmetric with the ``top_k >= 1`` check, in
    both the scalar and per-request forms."""
    with pytest.raises(ValueError, match=r"temperature.*\(request 0\)"):
        engine.serve(mixed_prompts[:2], 2, temperature=-1.0)
    with pytest.raises(ValueError, match=r"temperature.*\(request 1\)"):
        engine.serve(mixed_prompts[:2], 2, temperature=[0.5, float("nan")])
    with pytest.raises(ValueError, match=r"temperature.*\(request 1\)"):
        engine.serve(mixed_prompts[:2], 2, temperature=[0.5, -0.25])
    with pytest.raises(ValueError, match=r"top_k.*\(request 1\)"):
        engine.serve(mixed_prompts[:2], 2, top_k=[2, 0])
    # zero stays valid: it IS greedy decoding
    out = engine.serve(mixed_prompts[:1], 1, temperature=0.0)
    assert out[0].shape == (1,)


def test_top_k_one_is_greedy(engine, mixed_prompts):
    hot = engine.serve(mixed_prompts[:2], 6, temperature=HOT, top_k=1, seed=5)
    greedy = engine.serve(mixed_prompts[:2], 6)
    for a, b in zip(hot, greedy):
        np.testing.assert_array_equal(a, b)


def test_empty_prompt_validation(qwen, engine):
    with pytest.raises(ValueError, match="empty prompt"):
        engine.serve([np.zeros((0,), np.int32)], 4)
    _, model, params = qwen
    bos_engine = ServeEngine(model, params, max_len=16, max_batch=1, bos_id=1)
    out = bos_engine.serve([np.zeros((0,), np.int32)], 4)[0]
    assert out.shape == (4,)
    with pytest.raises(ValueError, match="max_len"):
        engine.serve([np.zeros((33,), np.int32)], 4)


def test_oversized_prompt_names_request_and_lengths(engine, mixed_prompts):
    """An over-long prompt must be rejected UP FRONT with the offending
    request index and both lengths in the message — not fail opaquely
    inside PrefillBuckets.bucket_for mid-serve, after other requests
    already ran."""
    bad = np.zeros((40,), np.int32)          # engine max_len is 32
    hits_before = dict(engine.buckets.hits)
    with pytest.raises(ValueError,
                       match=r"prompt 2 has length 40.*bucket 32"):
        engine.serve([mixed_prompts[0], mixed_prompts[1], bad], 4)
    # validation ran before any prefill: nothing was served or recorded
    assert engine.buckets.hits == hits_before
    # the index is the caller's position, also for empty prompts
    with pytest.raises(ValueError, match="index 1"):
        engine.serve([mixed_prompts[0], np.zeros((0,), np.int32)], 4)


# ---------------------------------------------------------------------------
# telemetry -> WorkloadProfile -> RTC
# ---------------------------------------------------------------------------
def test_telemetry_workload_profile(engine, mixed_prompts):
    """Serving traffic must flow into the paper's energy model: the
    engine-emitted profile is a sane decode-phase WorkloadProfile that
    rtc.evaluate accepts."""
    full = get_config("qwen1.5-0.5b")
    traffic = TrafficModel.from_config(full, max_len=4096)
    tele = ServeTelemetry(traffic)
    engine.serve(mixed_prompts, 6, telemetry=tele)

    assert tele.n_prefills == len(mixed_prompts)
    assert tele.prefill_tokens == sum(p.shape[0] for p in mixed_prompts)
    assert tele.tokens_generated == 6 * len(mixed_prompts)
    assert 1 <= tele.max_live <= engine.max_batch

    w = tele.workload_profile(name="qwen/serve", step_period_s=0.01)
    assert w.regular
    assert w.read_bytes_per_iter > traffic.param_read_bytes  # weights + KV
    assert w.write_bytes_per_iter > 0
    assert w.footprint_bytes == traffic.param_bytes \
        + tele.max_live * traffic.cache_slot_bytes

    spec = module(4)
    rep = evaluate(spec, w, Variant.FULL_RTC_PLUS)
    assert 0.0 < rep.refresh_savings <= 1.0


def test_traffic_model_accounting():
    """Byte constants follow directly from the config geometry."""
    cfg = get_config("gemma2-9b")       # (local, global) pattern
    t = TrafficModel.from_config(cfg, max_len=8192)
    itemsize = 2
    per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * itemsize
    assert t.kv_token_bytes == (per_layer,) * cfg.n_layers
    n_local = sum(cfg.layer_kind(i) == "local" for i in range(cfg.n_layers))
    assert sorted(set(t.kv_caps)) == sorted({8192, cfg.window_size})
    assert t.kv_caps.count(cfg.window_size) == n_local
    # reads are capped by each layer's cache length
    assert t.kv_read_bytes(10**9) == t.cache_slot_bytes - t.state_bytes
    assert t.kv_read_bytes(1) == cfg.n_layers * per_layer
    assert t.param_bytes == cfg.param_counts()["total"] * itemsize


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state.step) == 200


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    new, state = adamw_update(cfg, params, huge, state)
    # clipped grad -> bounded first step
    assert float(jnp.abs(new["w"]).max()) < 10.0


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.asarray(0))) < float(lr(jnp.asarray(9)))
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(lr(jnp.asarray(99))) == pytest.approx(0.1, abs=0.05)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
