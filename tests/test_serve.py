"""Serving engine + optimizer units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.serve.engine import ServeEngine
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, global_norm)


def test_serve_engine_greedy_deterministic():
    cfg = get_config("musicgen-medium", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=24)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 8)).astype(np.int32)
    a = engine.generate(prompts, 8, temperature=0.0)
    b = engine.generate(prompts, 8, temperature=0.0)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 8)
    assert (a >= 0).all() and (a < cfg.vocab_size).all()


def test_serve_engine_sampling_varies_with_seed():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=24)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    # randomly-initialized smoke models have near-degenerate logits
    # (one dominant token); a high temperature flattens them enough to
    # exercise the stochastic path
    a = engine.generate(prompts, 10, temperature=50.0, seed=1)
    b = engine.generate(prompts, 10, temperature=50.0, seed=2)
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state.step) == 200


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    new, state = adamw_update(cfg, params, huge, state)
    # clipped grad -> bounded first step
    assert float(jnp.abs(new["w"]).max()) < 10.0


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.asarray(0))) < float(lr(jnp.asarray(9)))
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(lr(jnp.asarray(99))) == pytest.approx(0.1, abs=0.05)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
