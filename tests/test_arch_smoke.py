"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED same-family config
and runs one forward + one train step on CPU, asserting output shapes
and the absence of NaNs; decoder paths additionally verify one decode
step against the full-sequence forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.frontends import synth_embeddings
from repro.models.transformer import TransformerLM


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))

    b, s = 2, 16
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (b, s)),
        jnp.int32)
    labels = (tokens + 1) % cfg.vocab_size

    if cfg.frontend == "vision":
        embeds = synth_embeddings(cfg, b, s)
        logits, aux = jax.jit(model.apply)(params, embeds=embeds)
        loss_fn = lambda p: model.loss(p, embeds=embeds, labels=labels)
    else:
        logits, aux = jax.jit(model.apply)(params, tokens)
        loss_fn = lambda p: model.loss(p, tokens=tokens, labels=labels)

    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"
    assert jnp.isfinite(aux)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.all(jnp.isfinite(g)), grads))
    assert all(bool(x) for x in leaves), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(1))
    b, s = 2, 8
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (b, s)),
        jnp.int32)
    full, _ = jax.jit(model.apply)(params, tokens)
    cache = model.init_cache(b, 16)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, tokens[:, t], jnp.asarray(t))
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_published_shape(arch):
    """The full config matches the assigned published dimensions."""
    expect = {
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def test_moe_configs():
    mix = get_config("mixtral-8x22b")
    assert (mix.n_experts, mix.experts_per_token) == (8, 2)
    dbrx = get_config("dbrx-132b")
    assert (dbrx.n_experts, dbrx.experts_per_token) == (16, 4)


def test_param_count_sanity():
    """Total params are within published ballparks."""
    bands = {
        "gemma-2b": (2.0e9, 3.0e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "gemma2-9b": (8.0e9, 11.0e9),
        "qwen1.5-0.5b": (0.4e9, 0.7e9),
        "mixtral-8x22b": (120e9, 160e9),
        "dbrx-132b": (110e9, 150e9),
        "falcon-mamba-7b": (6.0e9, 8.5e9),
        "recurrentgemma-2b": (2.2e9, 3.3e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        # backbone only (Qwen2-0.5B LM); the stubbed InternViT-300M
        # frontend is what brings the published total to ~0.9B
        "internvl2-1b": (0.4e9, 0.8e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, (arch, n)
