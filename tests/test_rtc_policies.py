"""RTC policy engine: paper-anchor validation + property tests."""
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import EVAL_MODULES, MODULE_2GB, MODULE_8GB, module
from repro.core.energy import system_power
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.workload import WorkloadProfile, from_cnn


def _eval(spec, w, var):
    alloc = allocate_workload(spec, {"d": w.footprint_bytes})
    return evaluate(spec, w, var, alloc)


# ---------------------------------------------------------------------------
# Paper anchors (Section VI text) — tolerance bands
# ---------------------------------------------------------------------------
def test_fig1_refresh_shares():
    for name, lo, hi in (("alexnet", 0.10, 0.22), ("googlenet", 0.08, 0.22),
                         ("lenet", 0.40, 0.54)):
        p = CNN_ZOO[name]
        sp = system_power(MODULE_2GB, from_cnn(p, 60), p.macs_per_frame * 60)
        assert lo <= sp["refresh_share"] <= hi, (name, sp["refresh_share"])


def test_alexnet_rtt_anchor_60fps():
    """Paper: Full-RTC RTT saves ~44% of DRAM energy for AN@60fps/2GB."""
    w = from_cnn(CNN_ZOO["alexnet"], 60)
    alloc = allocate_workload(MODULE_2GB, {"d": w.footprint_bytes})
    rtt, _ = rtt_paar_split(MODULE_2GB, w, alloc)
    assert 0.38 <= rtt <= 0.50, rtt


def test_alexnet_rtt_anchor_30fps():
    """Paper: ~30% at 30 fps (rate mismatch halves the coalescing)."""
    w = from_cnn(CNN_ZOO["alexnet"], 30)
    alloc = allocate_workload(MODULE_2GB, {"d": w.footprint_bytes})
    rtt, _ = rtt_paar_split(MODULE_2GB, w, alloc)
    assert 0.24 <= rtt <= 0.36, rtt


def test_lenet_paar_anchor():
    """Paper: LeNet's tiny footprint -> ~96% DRAM energy saving."""
    w = from_cnn(CNN_ZOO["lenet"], 60)
    rep = _eval(MODULE_2GB, w, Variant.FULL_RTC)
    assert 0.90 <= rep.dram_savings <= 0.995, rep.dram_savings


def test_full_rtc_selects_stronger_technique():
    """Paper Fig. 10a discussion: AN(60) uses RTT, LN(60) uses PAAR."""
    for cnn, which in (("alexnet", "rtt"), ("lenet", "paar")):
        w = from_cnn(CNN_ZOO[cnn], 60)
        alloc = allocate_workload(MODULE_2GB, {"d": w.footprint_bytes})
        rtt, paar = rtt_paar_split(MODULE_2GB, w, alloc)
        assert (rtt > paar) == (which == "rtt"), (cnn, rtt, paar)


def test_min_rtc_anchor_and_capacity_trend():
    """Paper: Min-RTC up to ~20% @2GB for AN, less at larger modules."""
    w = from_cnn(CNN_ZOO["alexnet"], 60)
    savings = [
        _eval(EVAL_MODULES[c], w, Variant.MIN_RTC).dram_savings
        for c in ("2GB", "4GB", "8GB")
    ]
    assert 0.14 <= savings[0] <= 0.26, savings
    assert savings[0] > savings[1] > savings[2]


def test_refresh_savings_range_matches_abstract():
    """Abstract: refresh-energy reduction 25%..96% across designs/CNNs."""
    vals = []
    for cnn in CNN_ZOO:
        for cap in EVAL_MODULES.values():
            for var in (Variant.MIN_RTC, Variant.MID_RTC, Variant.FULL_RTC):
                w = from_cnn(CNN_ZOO[cnn], 60)
                vals.append(_eval(cap, w, var).refresh_savings)
    assert min(vals) < 0.30 and max(vals) > 0.90


def test_smartrefresh_comparison():
    """Paper Fig. 11: RTC beats SmartRefresh everywhere (28%..96%)."""
    for cnn in CNN_ZOO:
        w = from_cnn(CNN_ZOO[cnn], 60)
        rtc = _eval(MODULE_8GB, w, Variant.FULL_RTC)
        smart = _eval(MODULE_8GB, w, Variant.SMART_REFRESH)
        assert rtc.dram_savings > smart.dram_savings, cnn


# ---------------------------------------------------------------------------
# Properties
# ---------------------------------------------------------------------------
wl = st.builds(
    WorkloadProfile,
    name=st.just("w"),
    footprint_bytes=st.integers(1 << 20, 1 << 30),
    iter_period_s=st.floats(1e-3, 0.5),
    read_bytes_per_iter=st.floats(1e6, 1e9),
    write_bytes_per_iter=st.floats(0, 1e8),
    regular=st.booleans(),
    row_utilization=st.floats(0.1, 1.0),
)


@given(wl, st.sampled_from(list(Variant)))
@settings(max_examples=120, deadline=None)
def test_savings_bounded_and_ordered(w, var):
    rep = _eval(MODULE_2GB, w, var)
    if var is Variant.SMART_REFRESH:
        # SmartRefresh may go NEGATIVE: its per-row counter array can
        # cost more than it saves (the paper's Section VI-B argument
        # for why RTC beats it at scale).
        assert -1.0 <= rep.dram_savings <= 1.0
    else:
        assert 0.0 <= rep.dram_savings <= 1.0
    assert 0.0 <= rep.refresh_savings <= 1.0
    base = _eval(MODULE_2GB, w, Variant.BASELINE)
    oracle = _eval(MODULE_2GB, w, Variant.NO_REFRESH)
    assert base.dram_savings == 0.0
    # No policy beats the no-refresh oracle by more than the AGU's
    # cmd/addr-bus elimination (RTC saves that *on top of* refresh —
    # Section IV-C2), which the oracle does not model.
    kappa_extra = 0.15 * rep.baseline.io / rep.baseline.total
    assert rep.dram_savings <= oracle.dram_savings + kappa_extra + 1e-9


@given(wl)
@settings(max_examples=60, deadline=None)
def test_variant_hierarchy(w):
    """More aggressive designs never save less (paper Section IV)."""
    mn = _eval(MODULE_2GB, w, Variant.MIN_RTC).dram_savings
    md = _eval(MODULE_2GB, w, Variant.MID_RTC).dram_savings
    fl = _eval(MODULE_2GB, w, Variant.FULL_RTC).dram_savings
    fp = _eval(MODULE_2GB, w, Variant.FULL_RTC_PLUS).dram_savings
    assert md >= mn - 1e-9
    assert fp >= fl - 1e-9


@given(wl)
@settings(max_examples=60, deadline=None)
def test_irregular_patterns_disable_rtt(w):
    import dataclasses
    w_irr = dataclasses.replace(w, regular=False)
    alloc = allocate_workload(MODULE_2GB, {"d": w_irr.footprint_bytes})
    rtt, _ = rtt_paar_split(MODULE_2GB, w_irr, alloc)
    assert rtt == 0.0
