"""Paged==contiguous equivalence suite for the block-table cache.

The contract under test: serving through the paged cache
(:class:`repro.serve.paging.PageTable` + ``PagedKVCache`` /
``PagedSSMCache`` / ``PagedRGLRUCache``) is *bit-identical* to serving
through the contiguous per-slot cache — prefill logits, every resident
cache page (the ``logical_view`` gather must reproduce the contiguous
buffers exactly), each decode step's logits, and the full generation
continuation.  This is what lets the engine grow a slot's page list
past the old contiguous ``max_len``, and offload cold pages to host
under a resident-page budget, without perturbing a single token.

Exercised per family: global append caches, local ring caches
(including page sizes that do not divide the ring length — partial
pages), Mamba/RG-LRU state pages and conv tails, and dropless-MoE
decode — i.e. all 10 ``repro.configs`` entries.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, PageTable, ServeEngine,
                         ServeTelemetry, TrafficModel, logical_view)

MAX_CTX = 24     # logical context capacity (and contiguous cache length)
BUCKET = 16      # padded prefill shape (one executable per arch)
MAX_PLEN = 12    # property-test prompt lengths: 1..MAX_PLEN
PAGE = 5         # deliberately not a divisor of MAX_CTX or any window

_CACHED = {}


def _arch(arch, page_size=PAGE):
    """(model, params, jitted padded prefill, jitted decode, jitted
    contiguous insert, PageTable) — cached per (arch, page_size)."""
    key = (arch, page_size)
    if key not in _CACHED:
        cfg = get_config(arch, smoke=True)
        model = TransformerLM(cfg)
        params = model.init(jax.random.key(0))
        prefill = jax.jit(
            lambda p, t, n: model.prefill(p, t, MAX_CTX, lengths=n))
        table = PageTable(model, max_batch=2, max_ctx=MAX_CTX,
                          page_size=page_size)
        _CACHED[key] = (model, params, prefill, jax.jit(model.decode_step),
                        jax.jit(ServeEngine._insert_cache), table)
    return _CACHED[key]


def _prefill_slot(model, params, prefill, row):
    padded = np.zeros((1, BUCKET), np.int32)
    padded[0, :row.shape[0]] = row
    return prefill(params, jnp.asarray(padded),
                   jnp.asarray([row.shape[0]], jnp.int32))


def _assert_views_equal(cache_c, cache_p, msg):
    """Every resident page, gathered back to the contiguous layout,
    must equal the contiguous cache bit-for-bit (including the zero
    rows of never-written positions)."""
    view = logical_view(cache_p)
    leaves_c = jax.tree_util.tree_flatten_with_path(cache_c)[0]
    leaves_p = jax.tree_util.tree_leaves(view)
    assert len(leaves_c) == len(leaves_p)
    for (path, a), b in zip(leaves_c, leaves_p):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{msg}: cache leaf {jax.tree_util.keystr(path)}")


def _build_pair(arch, plens, page_size=PAGE):
    """Admit ``plens`` prompts into slot 0/1 of both cache forms."""
    model, params, prefill, decode, insert, table = _arch(arch, page_size)
    cfg = model.cfg
    cache_c = model.init_cache(2, MAX_CTX)
    table.reset()
    cache_p = table.init_cache()
    toks = []
    for s, pl in enumerate(plens):
        row = np.random.default_rng(100 * pl + s).integers(
            0, cfg.vocab_size, (pl,)).astype(np.int32)
        logits, one = _prefill_slot(model, params, prefill, row)
        cache_c = insert(cache_c, one, jnp.asarray(s, jnp.int32))
        cache_p = table.admit(cache_p, one, s, pl)
        toks.append(int(jnp.argmax(logits[0])))
    return (model, params, decode, table, cache_c, cache_p,
            np.asarray(toks, np.int32), np.asarray(plens, np.int32))


def _lockstep(model, params, decode, table, cache_c, cache_p,
              tok, pos, steps, msg):
    """Decode both cache forms in lockstep, asserting bitwise equality
    of per-step logits and of every resident page after each step."""
    tok_c = tok_p = jnp.asarray(tok)
    for i in range(steps):
        for s in range(pos.shape[0]):
            cache_p, ok = table.prepare_step(cache_p, s, int(pos[s]))
            assert ok, f"{msg}: pool exhausted at step {i}"
        posj = jnp.asarray(pos)
        lc, cache_c = decode(params, cache_c, tok_c, posj)
        lp, cache_p = decode(params, cache_p, tok_p, posj)
        np.testing.assert_array_equal(
            np.asarray(lc), np.asarray(lp),
            err_msg=f"{msg}: decode step {i} logits")
        _assert_views_equal(cache_c, cache_p, f"{msg}: after step {i}")
        tok_c = jnp.argmax(lc, -1).astype(jnp.int32)
        tok_p = jnp.argmax(lp, -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok_c), np.asarray(tok_p),
                                      err_msg=f"{msg}: step {i} tokens")
        pos = pos + 1
    return cache_c, cache_p, tok_c, pos


def _check_arch(arch, plen):
    plens = (plen, (plen + 5) % MAX_PLEN + 1)   # mixed per-slot lengths
    (model, params, decode, table, cache_c, cache_p,
     tok, pos) = _build_pair(arch, plens)
    _assert_views_equal(cache_c, cache_p,
                        f"{arch} plens={plens}: after insert")
    # decode past BUCKET so growth allocates pages mid-flight
    steps = min(6, MAX_CTX - max(plens))
    _lockstep(model, params, decode, table, cache_c, cache_p, tok,
              pos, steps, f"{arch} plens={plens}")


@given(plen=st.integers(1, MAX_PLEN))
@settings(max_examples=4, deadline=None)
def test_paged_decode_bit_identical_all_archs(plen):
    """Property: for every configured arch, block-table paged decode is
    bit-identical to contiguous decode — prefill hand-off, every
    resident cache page, per-step logits, and the greedy continuation."""
    for arch in ARCH_IDS:
        _check_arch(arch, plen)


@pytest.mark.parametrize("page_size", [1, 3, 8, MAX_CTX])
def test_page_size_extremes(page_size):
    """Row-granular (1), partial-page (3), divisor (8) and whole-cache
    (MAX_CTX) page sizes all reproduce contiguous decode."""
    (model, params, decode, table, cache_c, cache_p,
     tok, pos) = _build_pair("qwen1.5-0.5b", (5, 9), page_size)
    _lockstep(model, params, decode, table, cache_c, cache_p, tok,
              pos, 6, f"page_size={page_size}")


# ---------------------------------------------------------------------------
# offload / restore round trip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_offload_round_trip_bit_exact(arch):
    """A slot's pages leave device memory (host offload) and re-enter —
    into different physical pool pages — bitwise unchanged, and the
    continued decode still matches the contiguous cache exactly."""
    (model, params, decode, table, cache_c, cache_p,
     tok, pos) = _build_pair(arch, (7, 10))
    cache_c, cache_p, tok, pos = _lockstep(
        model, params, decode, table, cache_c, cache_p, tok, pos, 3,
        f"{arch}: pre-offload")
    before = jax.tree.map(np.asarray, jax.tree.leaves(logical_view(cache_p)))

    cache_p, payload = table.offload(cache_p, 1, int(pos[1]))
    assert payload.tokens == int(pos[1])
    assert sum(k.nbytes + v.nbytes for _, k, v in payload.kv.values()) > 0 \
        or payload.state, "offload moved no bytes"
    # slot 1's rows are gone from the device view (block -> DUMP)...
    view_k = jax.tree.leaves(logical_view(cache_p))
    assert any(not np.array_equal(a, b) for a, b in zip(before, view_k))

    # ...and restore brings every page back bit-identically
    cache_p = table.restore(cache_p, 1, payload)
    after = jax.tree.map(np.asarray, jax.tree.leaves(logical_view(cache_p)))
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b, err_msg=f"{arch}: restore")
    _assert_views_equal(cache_c, cache_p, f"{arch}: post-restore")
    _lockstep(model, params, decode, table, cache_c, cache_p, tok, pos, 3,
              f"{arch}: post-restore decode")


def test_offload_unadmitted_slot_raises_named_error():
    """Offloading a slot that holds no pages (double preemption, or a
    scheduler bug picking a retired victim) must fail as a named
    :class:`PageTableError` carrying the slot, the stream, and the
    live-slot set — not as a bare ``KeyError`` out of the allocator's
    bookkeeping — and must not corrupt the table on the way out."""
    from repro.serve.paging import PageTableError

    (model, params, decode, table, cache_c, cache_p,
     tok, pos) = _build_pair("qwen1.5-0.5b", (7, 10))
    cache_p, payload = table.offload(cache_p, 1, int(pos[1]))
    with pytest.raises(PageTableError) as ei:
        table.offload(cache_p, 1, int(pos[1]))
    msg = str(ei.value)
    assert "slot 1 holds no pages" in msg
    assert "groups" in msg                     # the stream is named
    assert "live slots there: [0]" in msg      # the still-admitted set
    # the failed call mutated nothing: restore + decode stay bit-exact
    cache_p = table.restore(cache_p, 1, payload)
    _assert_views_equal(cache_c, cache_p, "post-error restore")
    _lockstep(model, params, decode, table, cache_c, cache_p, tok, pos, 2,
              "qwen1.5-0.5b: post-error decode")


def test_prepare_step_commits_partial_progress_and_retry_is_exact():
    """Pool exhaustion mid-``prepare_step``: assignments for streams
    visited before the exhausted one stay committed (the documented
    invariant) — the retry after pages free up skips them, allocates
    only the missing streams, and the continued decode stays
    bit-identical to the contiguous cache, i.e. to a serve that never
    exhausted the pool."""
    (model, params, decode, table, cache_c, cache_p,
     tok, pos) = _build_pair("gemma2-9b", (3, 10))
    local, glob = [st for st in table.streams if not st.is_state]
    assert local.kind == "local" and glob.kind == "global"
    # pos 5 crosses a page boundary in BOTH streams for slot 0; empty
    # the global stream's free list so the local assignment commits and
    # the global one exhausts
    stolen, glob.free[0] = glob.free[0], []
    cache_p, ok = table.prepare_step(cache_p, 0, 5)
    assert not ok
    assert 1 in local.slot_pages[0]        # partial progress committed
    assert 1 not in glob.slot_pages[0]
    committed = local.slot_pages[0][1]
    # a victim's pages return (engine preemption) -> the retry
    # succeeds, reusing the committed page instead of re-allocating
    glob.free[0] = stolen
    cache_p, ok = table.prepare_step(cache_p, 0, 5)
    assert ok
    assert local.slot_pages[0][1] == committed
    assert 1 in glob.slot_pages[0]
    _lockstep(model, params, decode, table, cache_c, cache_p, tok, pos, 4,
              "gemma2-9b: post-retry decode")


# ---------------------------------------------------------------------------
# engine level: past-max_len decode, preemption, all archs
# ---------------------------------------------------------------------------
def _engine_pair(arch, paged_kw, ref_max_len, max_batch=2):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    paged_max_len = paged_kw.pop("_max_len", ref_max_len)
    ref = ServeEngine(model, params, max_len=ref_max_len,
                      max_batch=max_batch)
    pag = ServeEngine(model, params, max_len=paged_max_len,
                      max_batch=max_batch,
                      paged=PagedCacheConfig(**paged_kw))
    return cfg, ref, pag


def test_decode_past_contiguous_max_len():
    """Acceptance: a request whose prompt+generation exceeds the old
    contiguous per-slot cap completes through paged decode — and
    matches a big-contiguous-cache engine bit-for-bit (the prefill
    bucket cap stays at 8 while decode grows to 28 tokens)."""
    cfg, ref, pag = _engine_pair(
        "qwen1.5-0.5b",
        {"page_size": 4, "max_ctx": 32, "_max_len": 8}, ref_max_len=32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (6, 3, 8)]
    a = ref.serve(prompts, 20, seed=5)
    b = pag.serve(prompts, 20, seed=5)
    for i, (x, y) in enumerate(zip(a, b)):
        assert x.shape[0] == 20          # past the old max_len=8 cap
        np.testing.assert_array_equal(x, y, err_msg=f"request {i}")


@pytest.mark.slow_serve
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_paged_engine_matches_contiguous_all_archs(arch):
    """Acceptance: on every arch, a tight-budget paged engine (growth
    past the prefill cap + forced preemption/offload) serves a mixed
    greedy+stochastic workload bit-identically to an ample contiguous
    engine."""
    cfg, ref, pag = _engine_pair(
        arch, {"page_size": 8, "max_ctx": 32, "resident_pages": 6,
               "_max_len": 16}, ref_max_len=32)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9, 3)]
    temps, topks = [0.0, 50.0, 50.0], [None, None, 5]
    a = ref.serve(prompts, 20, temperature=temps, top_k=topks, seed=11)
    b = pag.serve(prompts, 20, temperature=temps, top_k=topks, seed=11)
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"{arch} request {i}")


# ---------------------------------------------------------------------------
# telemetry: page traffic + exact-sum invariant
# ---------------------------------------------------------------------------
class _RecordingTelemetry(ServeTelemetry):
    """Keeps the raw event stream so the test can re-derive every byte
    independently of the accumulator implementation."""

    def __init__(self, traffic, **kw):
        super().__init__(traffic, **kw)
        self.events = []

    def record_prefill(self, plen, dt=0.0, padded_len=None):
        self.events.append(("prefill", plen, padded_len))
        super().record_prefill(plen, dt, padded_len=padded_len)

    def record_decode(self, ctx_lengths, dt=0.0):
        self.events.append(("decode", tuple(int(c) for c in ctx_lengths)))
        super().record_decode(ctx_lengths, dt)

    def record_page_out(self, ctx):
        self.events.append(("page_out", int(ctx)))
        super().record_page_out(ctx)

    def record_page_in(self, ctx):
        self.events.append(("page_in", int(ctx)))
        super().record_page_in(ctx)


def test_telemetry_page_bytes_and_exact_invariant():
    """Acceptance: page-in/page-out bytes are nonzero when the
    resident-page budget forces offload, they flow into the
    WorkloadProfile, and the profile equals the per-event byte sums
    EXACTLY — decode traffic from decode events only (prefill pad waste
    is never double-counted into DRAM bytes).  The engine's gather
    backend additionally pays the materialized logical view per live
    slot per step (the phantom traffic the pallas_paged kernel
    removes), which the reconstruction must reproduce too."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(
        model, params, max_len=48, max_batch=3,
        paged=PagedCacheConfig(page_size=8, resident_pages=8))
    t = TrafficModel.from_config(get_config("qwen1.5-0.5b"), max_len=4096,
                                 page_size=8)
    tele = _RecordingTelemetry(t)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9, 3)]
    engine.serve(prompts, 30, telemetry=tele)
    assert tele.decode_mode == "gather"   # engine-configured

    # the tight budget forced offload traffic, and it reached the profile
    assert tele.page_outs > 0 and tele.page_ins > 0
    assert tele.page_out_bytes_total > 0 and tele.page_in_bytes_total > 0

    # independent per-event reconstruction
    param_total = kv_total = write_total = po_total = pi_total = 0
    gr_total = gw_total = 0
    n_steps = 0
    for ev in tele.events:
        if ev[0] == "decode":
            ctx = ev[1]
            n_steps += 1
            param_total += t.param_read_bytes
            kv_total += t.state_bytes * len(ctx) \
                + sum(t.kv_read_bytes(c) for c in ctx)
            write_total += (t.kv_write_bytes + t.state_bytes) * len(ctx)
            gr_total += t.gather_view_read_bytes * len(ctx)
            gw_total += t.gather_view_write_bytes * len(ctx)
        elif ev[0] == "page_out":
            po_total += t.page_bytes(ev[1])
        elif ev[0] == "page_in":
            pi_total += t.page_bytes(ev[1])
    assert n_steps == tele.decode_steps
    assert po_total == tele.page_out_bytes_total
    assert pi_total == tele.page_in_bytes_total
    assert gr_total == tele.gather_read_bytes_total
    assert gw_total == tele.gather_write_bytes_total

    w = tele.workload_profile(step_period_s=0.01)
    n = tele.decode_steps
    assert w.read_bytes_per_iter == \
        param_total / n + kv_total / n + gr_total / n + po_total / n
    assert w.write_bytes_per_iter == \
        write_total / n + gw_total / n + pi_total / n

    # page moves are whole pages: ctx 5 rounds up to one 8-token page
    # per global layer (+ state); never less than the row-exact bytes
    exact = dataclasses.replace(t, page_size=0)
    assert t.page_bytes(5) >= exact.page_bytes(5)
    assert t.page_bytes(5) == exact.page_bytes(8)


def test_paged_telemetry_zero_without_pressure():
    """An ample budget never offloads: page counters stay zero.  The
    gather backend still pays its materialized-view traffic every step
    (pressure-independent — that's why the kernel backend exists), and
    pinning ``decode_mode="contiguous"`` recovers the row-exact
    profile."""
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=32, max_batch=2,
                         paged=PagedCacheConfig(page_size=8))
    t = TrafficModel.from_config(get_config("qwen1.5-0.5b"), max_len=4096)
    tele = ServeTelemetry(t)
    pinned = ServeTelemetry(t, decode_mode="contiguous")
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    engine.serve([prompt], 6, telemetry=tele)
    engine.serve([prompt], 6, telemetry=pinned)
    for s in (tele, pinned):
        assert s.page_outs == s.page_ins == 0
        assert s.page_out_bytes_total == s.page_in_bytes_total == 0
    # engine-configured gather accounting: one view read+write per live
    # slot per step on top of the row-exact sweep
    assert tele.decode_mode == "gather"
    n = tele.decode_steps
    assert tele.gather_read_bytes_total == n * t.gather_view_read_bytes
    assert tele.gather_write_bytes_total == n * t.gather_view_write_bytes
    w = tele.workload_profile(step_period_s=0.01)
    assert w.read_bytes_per_iter == \
        (tele.param_read_bytes_total + tele.kv_read_bytes_total
         + tele.gather_read_bytes_total) / n
    # the pinned sink keeps the seed (row-exact) accounting
    assert pinned.decode_mode == "contiguous"
    assert pinned.gather_read_bytes_total == 0
    wp = pinned.workload_profile(step_period_s=0.01)
    assert wp.read_bytes_per_iter == \
        (pinned.param_read_bytes_total + pinned.kv_read_bytes_total) \
        / pinned.decode_steps


# ---------------------------------------------------------------------------
# PageTable policy
# ---------------------------------------------------------------------------
def test_page_table_budget_floor():
    """A budget that cannot hold one fully decoded slot is rejected at
    construction (it could deadlock with every other slot offloaded)."""
    model, params, *_ = _arch("qwen1.5-0.5b")
    with pytest.raises(ValueError, match="resident_pages"):
        PageTable(model, max_batch=2, max_ctx=MAX_CTX, page_size=8,
                  resident_pages=2)   # needs ceil(24/8) = 3
    with pytest.raises(ValueError, match="page_size"):
        PageTable(model, max_batch=2, max_ctx=MAX_CTX, page_size=0)
    with pytest.raises(ValueError, match="max_ctx"):
        ServeEngine(model, params, max_len=32, max_batch=1,
                    paged=PagedCacheConfig(page_size=8, max_ctx=16))


def test_paged_config_validates_eagerly():
    """A bad PagedCacheConfig fails at construction / engine entry with
    the offending field named — never deep inside PageTable after the
    prefill executables already lowered."""
    with pytest.raises(ValueError, match="PagedCacheConfig.page_size"):
        PagedCacheConfig(page_size=0)
    with pytest.raises(ValueError, match="PagedCacheConfig.resident_pages"):
        PagedCacheConfig(resident_pages=0)
    with pytest.raises(ValueError, match="PagedCacheConfig.max_ctx"):
        PagedCacheConfig(max_ctx=-4)

    model, params, *_ = _arch("qwen1.5-0.5b")
    cfg = model.cfg
    bad = PagedCacheConfig(page_size=8, resident_pages=2, max_ctx=MAX_CTX)
    # the floor needs the model's layer mix: validate() names the field
    with pytest.raises(ValueError, match="PagedCacheConfig.resident_pages"):
        bad.validate(cfg)
    assert bad.slot_floor(cfg, MAX_CTX) == 3     # ceil(24/8)
    # the engine applies the same check before lowering anything: abuse
    # abstract params — if validation were lazy, tracing would fail
    # first with an unrelated error
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(ValueError, match="PagedCacheConfig.resident_pages"):
        ServeEngine(model, shapes, max_len=16, max_batch=2, paged=bad)
    # a config with no max_ctx anywhere cannot be validated
    with pytest.raises(ValueError, match="max_ctx"):
        PagedCacheConfig(page_size=8).validate(cfg)


def test_allocate_on_write_and_free_on_retire():
    """Admission takes exactly ceil(min(plen, cache_len)/page) pages per
    KV stream (+1 state page per recurrent stream); retire returns
    every page to the free list."""
    model, params, prefill, _, _, table = _arch("recurrentgemma-2b")
    table.reset()
    cache = table.init_cache()
    free0 = table.free_page_counts()
    row = np.arange(7, dtype=np.int32) % model.cfg.vocab_size
    _, one = _prefill_slot(model, params, prefill, row)
    cache = table.admit(cache, one, 0, 7)
    for stream in table.streams:
        held = stream.slot_pages[0]
        if stream.is_state:
            assert isinstance(held, int)
        else:
            # window=8 ring, PAGE=5: 7 rows -> 2 pages; global would
            # also take 2 (ceil(7/5))
            assert len(held) == -(-min(7, stream.cache_len) // PAGE)
    cache = table.release(cache, 0)
    assert table.free_page_counts() == free0
    assert all(not s.slot_pages for s in table.streams)
