"""The paper's full evaluation pipeline as one script: CNN profiles ->
workloads -> RTC variants x module capacities, with the event-level
simulator validating the analytic numbers on a downscaled module.

    PYTHONPATH=src python examples/rtc_energy_study.py
"""
from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import DRAMSpec, EVAL_MODULES
from repro.core.refresh_sim import simulate
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.workload import from_cnn

print(f"{'cnn':<11}{'dram':<6}{'fps':<5}{'RTT':>7}{'PAAR':>7}"
      f"{'full':>7}{'mid':>7}{'min':>7}{'full+':>7}")
for cap, spec in EVAL_MODULES.items():
    for cnn, prof in CNN_ZOO.items():
        for fps in (30, 60):
            w = from_cnn(prof, fps)
            alloc = allocate_workload(spec, {"d": w.footprint_bytes})
            rtt, paar = rtt_paar_split(spec, w, alloc)
            row = [
                evaluate(spec, w, v, alloc).dram_savings
                for v in (Variant.FULL_RTC, Variant.MID_RTC,
                          Variant.MIN_RTC, Variant.FULL_RTC_PLUS)
            ]
            print(f"{cnn:<11}{cap:<6}{fps:<5}{rtt:>7.1%}{paar:>7.1%}"
                  f"{row[0]:>7.1%}{row[1]:>7.1%}{row[2]:>7.1%}"
                  f"{row[3]:>7.1%}")

print("\nevent-level cross-check (64k-row module, streaming pattern):")
small = DRAMSpec(capacity_bytes=65536 * 2048)
for na in (4096, 16384, 65536):
    r = simulate(small, Variant.FULL_RTC, alloc_rows=16384,
                 rows_accessed_per_window=min(na, 16384), n_windows=16)
    print(f"  rows/window={na:>6}: refresh savings {r.refresh_savings:.3f} "
          f"violations={r.violations}")
