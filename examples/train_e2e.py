"""End-to-end training example: sharded trainer with checkpoints and a
mid-run simulated preemption + restart.

Defaults to smoke scale (CPU container); ``--full`` trains the real
smollm-360m config (use on actual accelerators).

    PYTHONPATH=src python examples/train_e2e.py --steps 30
"""
import argparse
import tempfile

import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.dist.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh
from repro.models.transformer import TransformerLM
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    model = TransformerLM(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    policy = ShardingPolicy.for_mesh(mesh)
    data = SyntheticLMData(cfg.vocab_size, args.batch, args.seq)

    with tempfile.TemporaryDirectory() as ckpt:
        def mk():
            return Trainer(model, AdamWConfig(lr=1e-3,
                                              total_steps=args.steps * 2),
                           mesh, policy, data, ckpt_dir=ckpt,
                           ckpt_every=max(2, args.steps // 3))

        half = args.steps // 2
        t = mk()
        r1 = t.run(half)
        print(f"phase 1: {r1.steps_run} steps, "
              f"loss {r1.losses[0]:.4f} -> {r1.losses[-1]:.4f}")

        # simulate a node failure: new Trainer == new process
        t2 = mk()
        r2 = t2.run(args.steps - half)
        print(f"phase 2 (resumed from step {r2.resumed_from}): "
              f"{r2.steps_run} steps, loss -> {r2.losses[-1]:.4f}")
        assert r2.resumed_from is not None
        assert np.isfinite(r2.losses).all()
        print("restart-exactness and finiteness checks passed")


if __name__ == "__main__":
    main()
