"""End-to-end driver (the paper's kind is inference/energy): serve a
small model with batched requests.

Prefills a batch of prompts, decodes with temperature sampling, and
reports throughput — then estimates the DRAM refresh energy RTC would
save for this exact serving loop (weights re-streamed every step), the
paper's mechanism applied to the system we just ran.

    PYTHONPATH=src python examples/serve_batched.py [--new-tokens 48]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.allocator import allocate_workload
from repro.core.dram import module
from repro.core.rtc import Variant, evaluate
from repro.core.trace import lm_workload
from repro.models.transformer import TransformerLM
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens,
                          temperature=args.temperature)
    dt = time.time() - t0
    step_time = dt / (args.prompt_len + args.new_tokens)
    print(f"served {args.batch} requests x {args.new_tokens} new tokens "
          f"in {dt:.2f}s -> {args.batch*args.new_tokens/dt:.1f} tok/s")
    print(f"sample continuation: {out[0][:10].tolist()}")

    # RTC on THIS loop (weights in LPDDR-class memory, edge serving):
    full = get_config(args.arch)  # energy study uses the real footprint
    w = lm_workload(full, "decode", step_time,
                    global_batch=args.batch, seq_len=4096)
    spec = module(4)
    alloc = allocate_workload(spec, {"weights": w.footprint_bytes})
    rep = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
    print(f"\nRTC on this serving loop ({full.name}, 4 GB module): "
          f"refresh energy -{rep.refresh_savings:.1%}, "
          f"DRAM energy -{rep.dram_savings:.1%}")


if __name__ == "__main__":
    main()
