"""End-to-end driver (the paper's kind is inference/energy): serve a
small model with continuous batching.

Admits a queue of mixed-length prompts into the engine's batch slots
(one-shot length-bucketed prefill each: prompts are right-padded to a
small bucket ladder so a handful of lowered executables serves any
length mix, with masked positions guaranteeing padding cannot perturb a
generation), decodes with temperature sampling and per-slot positions,
retires/refills slots mid-flight, and reports throughput plus the
bucket ladder's pad-waste accounting — then evaluates the DRAM refresh
energy RTC would save for this exact serving loop from the *engine's
own telemetry* (per-step weight + KV-cache traffic, prefill accounted
from true prompt lengths), the paper's mechanism applied to the system
we just ran.

    PYTHONPATH=src python examples/serve_batched.py [--new-tokens 48]
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.allocator import allocate_workload
from repro.core.dram import GiB, smallest_fitting_module
from repro.core.rtc import Variant, evaluate
from repro.models.transformer import TransformerLM
from repro.serve import (PagedCacheConfig, ServeEngine, ServeTelemetry,
                         TrafficModel)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--serve-ctx", type=int, default=4096,
                    help="deployment context for the energy model")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-table paged cache")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--resident-pages", type=int, default=None,
                    help="device page budget per KV stream; tight values "
                         "force host offload (paged mode)")
    ap.add_argument("--decode-backend", default="gather",
                    choices=("gather", "pallas_paged"),
                    help="paged attention path: materialize the logical "
                         "view (gather) or read pages in place through "
                         "the block-table Pallas kernel (pallas_paged)")
    ap.add_argument("--audit", action="store_true",
                    help="statically audit the engine's lowered decode "
                         "step (repro.analysis) and print the per-class "
                         "byte cross-check against telemetry's model")
    ap.add_argument("--trace-rtc", action="store_true",
                    help="record the per-step page-access trace and "
                         "replay it through the event-level refresh "
                         "simulator under every DRAM placement policy "
                         "(paged mode)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="prepend a common system-prompt prefix to every "
                         "request and serve through the content-addressed "
                         "COW page table, printing the shared-page "
                         "traffic savings (paged mode)")
    args = ap.parse_args()
    if args.decode_backend == "pallas_paged" and not args.paged:
        ap.error("--decode-backend pallas_paged requires --paged")
    if args.trace_rtc and not args.paged:
        ap.error("--trace-rtc requires --paged (page-access traces come "
                 "from the page table)")
    if args.prefix_share and not args.paged:
        ap.error("--prefix-share requires --paged (sharing lives in the "
                 "page table)")

    cfg = get_config(args.arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    max_len = args.max_prompt_len + args.new_tokens
    sharing = None
    if args.prefix_share:
        from repro.serve import PrefixSharingConfig
        sharing = PrefixSharingConfig()
        max_len += args.page_size          # room for the shared prefix
    paged = PagedCacheConfig(page_size=args.page_size,
                             resident_pages=args.resident_pages,
                             sharing=sharing) \
        if args.paged else None
    engine = ServeEngine(model, params, max_len=max_len,
                         max_batch=args.max_batch, paged=paged,
                         decode_backend=args.decode_backend)

    # energy accounting uses the full-size config's byte constants, with
    # the smoke run's per-slot occupancies extrapolated to the
    # deployment context (ctx_scale) so KV traffic and cache footprint
    # describe the same serve_ctx-sized deployment.
    full = get_config(args.arch)
    trace = None
    if args.trace_rtc:
        from repro.core.trace import PageAccessTrace
        trace = PageAccessTrace(engine.page_table.stream_names())
    tele = ServeTelemetry(
        TrafficModel.from_config(full, args.serve_ctx,
                                 page_size=args.page_size if args.paged else 0),
        ctx_scale=args.serve_ctx / max_len, trace=trace)

    rng = np.random.default_rng(0)
    lens = rng.integers(1, args.max_prompt_len + 1, args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(n),)).astype(np.int32)
               for n in lens]
    if args.prefix_share:
        # every request carries the same page-aligned "system prompt";
        # the second repeats the first verbatim (admitted while the
        # original is live, so the whole-prompt memo's full prefill
        # skip fires — sharing is in-flight only)
        system = rng.integers(0, cfg.vocab_size,
                              (args.page_size,)).astype(np.int32)
        prompts = [np.concatenate([system, p]) for p in prompts]
        if len(prompts) > 1:
            prompts[1] = prompts[0].copy()
        lens = np.asarray([p.shape[0] for p in prompts])
    t0 = time.time()
    outs = engine.serve(prompts, args.new_tokens,
                        temperature=args.temperature, telemetry=tele)
    dt = time.time() - t0
    n_tok = sum(o.shape[0] for o in outs)
    print(f"served {args.requests} requests (prompt lens "
          f"{lens.min()}..{lens.max()}) on {args.max_batch} slots: "
          f"{n_tok} tokens in {dt:.2f}s -> {n_tok/dt:.1f} tok/s "
          f"({tele.decode_steps} decode steps, {tele.n_prefills} prefills)")
    print(f"prefill {engine.buckets.summary()}; "
          f"{engine.prefill_executables} lowered prefill executables "
          f"for {len(set(int(n) for n in lens))} distinct prompt lengths")
    if args.paged:
        print(f"paged cache: page={args.page_size} tokens, "
              f"budget={engine.page_table.resident_pages} pages/stream; "
              f"{tele.page_outs} offloads / {tele.page_ins} restores "
              f"({tele.page_out_bytes_total + tele.page_in_bytes_total:,} "
              f"deployment-scale bytes of page traffic)")
        phantom = tele.gather_read_bytes_total + tele.gather_write_bytes_total
        if args.decode_backend == "pallas_paged":
            print(f"decode backend pallas_paged: per-page KV + recurrent-"
                  f"state reads only ({tele.kv_read_bytes_total:,} bytes), "
                  f"no materialized-view traffic")
        else:
            print(f"decode backend gather: {phantom:,} bytes of "
                  f"materialized-view traffic on top of the "
                  f"{tele.kv_read_bytes_total:,}-byte KV + state sweep "
                  f"(the copy the pallas_paged kernel never makes)")
    if args.prefix_share:
        st = engine.page_table.stats
        booked = tele.prefix_hit_bytes_total + tele.admit_write_bytes_total
        print(f"prefix sharing: {st['pages_registered']} pages registered, "
              f"{st['pages_attached']} attached (refcounted, not "
              f"re-allocated), {st['cow_forks']} COW forks, "
              f"{tele.prefix_full_skips} full prefill skips; "
              f"{tele.prefix_hit_bytes_total:,} of {booked:,} admission "
              f"bytes served from shared pages "
              f"(-{tele.prefix_hit_frac:.1%})")
        if not (tele.prefix_hit_tokens > 0 and st["pages_attached"] > 0):
            raise SystemExit("--prefix-share: the common prefix produced "
                             "no shared-page hits")
    print(f"sample continuation: {outs[0][:10].tolist()}")

    if args.trace_rtc:
        # replay the measured page-access stream through the event-level
        # refresh simulator: one DRAM module sized to the engine's own
        # pools, every placement policy as a column
        from repro.core.placement import (PLACEMENT_POLICIES,
                                          build_placement, fitting_spec)
        from repro.core.refresh_sim import simulate_trace
        from repro.core.trace import window_masks
        itemsize = {"bfloat16": 2, "float16": 2, "float32": 4}[cfg.dtype]
        pbytes = cfg.param_counts()["total"] * itemsize
        geoms = engine.page_table.stream_geometries()
        tspec = fitting_spec(geoms, param_bytes=pbytes)
        print(f"\ntrace-driven RTC replay ({trace.n_steps} steps, "
              f"{tspec.n_rows} rows):")
        for policy in PLACEMENT_POLICIES:
            placement = build_placement(policy, tspec, geoms,
                                        param_bytes=pbytes)
            masks = window_masks(trace, placement)
            res = simulate_trace(tspec, Variant.FULL_RTC, masks=masks,
                                 alloc_lo=placement.alloc_lo,
                                 alloc_rows=placement.alloc_rows)
            assert res.violations == 0, (policy, res)
            print(f"  {policy:<17s} alloc={placement.alloc_rows:>6d} rows "
                  f"touched/win={masks.sum(axis=1).mean():.0f} "
                  f"full-rtc refresh -{res.refresh_savings:.1%}")

    if args.audit:
        # static cross-check: walk the decode executable we just served
        # through and compare its jaxpr-derived per-class bytes (full
        # occupancy, smoke scale) against TrafficModel's analytic twin
        from repro.analysis import decode_traffic_report, unit_from_engine
        rep = decode_traffic_report(unit_from_engine(engine, args.arch))
        print("\nstatic audit of the lowered decode step "
              "(bytes/step, full occupancy, smoke scale):")
        print(f"  {'class':<20s} {'jaxpr-derived':>14s} {'telemetry':>14s}")
        for k in sorted(rep["expected"]):
            d, e = rep["derived"].get(k, 0), rep["expected"][k]
            mark = "" if d == e else "   <-- DRIFT"
            print(f"  {k:<20s} {d:>14,d} {e:>14,d}{mark}")
        print("  agreement: " + ("exact" if rep["match"] else
                                 "DRIFT (run python -m repro.analysis)"))
        audit_ok = rep["match"]
    else:
        audit_ok = True

    # RTC on THIS loop (weights in LPDDR-class memory, edge serving):
    w = tele.workload_profile(name=f"{full.name}/serve")
    spec = smallest_fitting_module(w.footprint_bytes)
    gb = spec.capacity_bytes // GiB
    alloc = allocate_workload(spec, {"serve": w.footprint_bytes})
    rep = evaluate(spec, w, Variant.FULL_RTC_PLUS, alloc)
    print(f"\nRTC on this serving loop ({full.name}, {gb} GB module, "
          f"engine-measured traffic {w.traffic_bytes_per_s/1e9:.2f} GB/s): "
          f"refresh energy -{rep.refresh_savings:.1%}, "
          f"DRAM energy -{rep.dram_savings:.1%}")
    # --audit is a gate, not a printout: scripted callers (CI smoke)
    # must see the static-vs-telemetry drift as a failing exit status
    return 0 if audit_ok else 1


if __name__ == "__main__":
    sys.exit(main())
