"""Quickstart: the two halves of this framework in ~60 lines.

1. The PAPER: evaluate Refresh Triggered Computation on AlexNet@60fps
   (analytic engine + event-level simulator cross-check).
2. The SYSTEM: build an assigned architecture from the registry, run a
   training step and a decode step on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# --- 1. RTC on the paper's workload ----------------------------------------
from repro.core.allocator import allocate_workload
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.dram import DRAMSpec, MODULE_2GB
from repro.core.refresh_sim import simulate
from repro.core.rtc import Variant, evaluate, rtt_paar_split
from repro.core.workload import from_cnn

print("== RTC on AlexNet@60fps, 2 GB LPDDR4 module ==")
w = from_cnn(CNN_ZOO["alexnet"], fps=60)
alloc = allocate_workload(MODULE_2GB, {"alexnet": w.footprint_bytes})
rtt, paar = rtt_paar_split(MODULE_2GB, w, alloc)
print(f"RTT-only saves {rtt:.1%} of DRAM energy, PAAR-only {paar:.1%}")
for var in (Variant.MIN_RTC, Variant.MID_RTC, Variant.FULL_RTC):
    rep = evaluate(MODULE_2GB, w, var, alloc)
    print(f"{var.value:>10}: DRAM energy -{rep.dram_savings:.1%} "
          f"(refresh -{rep.refresh_savings:.1%})")

print("\n== event-level simulator (downscaled module) ==")
small = DRAMSpec(capacity_bytes=65536 * 2048)
sim = simulate(small, Variant.FULL_RTC, alloc_rows=16384,
               rows_accessed_per_window=8192, n_windows=16)
print(f"explicit refreshes {sim.explicit_refreshes:,}, "
      f"implicit {sim.implicit_refreshes:,}, "
      f"violations {sim.violations} (must be 0), "
      f"refresh savings {sim.refresh_savings:.1%}")

# --- 2. An assigned architecture end to end ---------------------------------
from repro.configs import get_config
from repro.models.transformer import TransformerLM

print("\n== gemma2-9b (reduced smoke config) train + decode step ==")
cfg = get_config("gemma2-9b", smoke=True)
model = TransformerLM(cfg)
params = model.init(jax.random.key(0))
tokens = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab_size, (2, 16)), jnp.int32)
loss, grads = jax.jit(jax.value_and_grad(
    lambda p: model.loss(p, tokens=tokens,
                         labels=(tokens + 1) % cfg.vocab_size)))(params)
print(f"train loss {float(loss):.3f} (grads finite: "
      f"{all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))})")

cache = model.init_cache(2, 32)
logits, cache = jax.jit(model.decode_step)(
    params, cache, tokens[:, 0], jnp.asarray(0))
print(f"decode logits {logits.shape}, argmax {jnp.argmax(logits, -1)}")
